//! Model-level execution API over the compiled artifacts.
//!
//! [`CoModel`] is one co-inference model pair: the agent-side encoder
//! (runs with *quantized* weights, paper eq. 1) and the server-side
//! decoder (full precision, eq. 2). [`Fcdnn`] is the Fig.-3 verification
//! model and [`QuantKernel`] exposes the standalone Pallas fake-quant
//! modules for Rust-vs-XLA cross-checks.

use crate::quant::Scheme;
use crate::runtime::artifact::Registry;
use crate::runtime::client::{literal_f32, literal_scalar, Executable};
use crate::runtime::weights::WeightStore;
use crate::util::json::Json;
use anyhow::{Context, Result};
use std::collections::HashMap;
use std::rc::Rc;

/// Geometry read from the manifest config.
#[derive(Debug, Clone)]
pub struct ModelDims {
    pub input: Vec<usize>,
    pub emb_tokens: usize,
    pub d_model: usize,
    pub max_len: usize,
    pub vocab: usize,
    pub batches: Vec<usize>,
}

impl ModelDims {
    pub fn input_len(&self) -> usize {
        self.input.iter().product()
    }

    pub fn emb_len(&self) -> usize {
        self.emb_tokens * self.d_model
    }

    fn from_manifest(cfg: &Json) -> Result<ModelDims> {
        let usize_field = |k: &str| -> Result<usize> {
            cfg.get(k)
                .and_then(Json::as_usize)
                .with_context(|| format!("config.{k} missing"))
        };
        Ok(ModelDims {
            input: cfg
                .get("input_shape")
                .and_then(Json::as_arr)
                .context("input_shape")?
                .iter()
                .filter_map(Json::as_usize)
                .collect(),
            emb_tokens: usize_field("emb_tokens")?,
            d_model: usize_field("d_model")?,
            max_len: usize_field("max_len")?,
            vocab: usize_field("vocab")?,
            batches: cfg
                .get("batches")
                .and_then(Json::as_arr)
                .context("batches")?
                .iter()
                .filter_map(Json::as_usize)
                .collect(),
        })
    }
}

/// One co-inference model (agent encoder + server decoder).
pub struct CoModel {
    pub name: String,
    pub dims: ModelDims,
    agent_exes: HashMap<usize, Rc<Executable>>,
    server_exes: HashMap<usize, Rc<Executable>>,
    pub agent_weights: WeightStore,
    pub server_weights: WeightStore,
    pub agent_flops: f64,
    pub server_flops: f64,
}

impl CoModel {
    pub fn load(reg: &Registry, name: &str) -> Result<CoModel> {
        let entry = reg.model(name)?.clone();
        let dims = ModelDims::from_manifest(entry.get("config").context("config missing")?)?;
        let mut agent_exes = HashMap::new();
        let mut server_exes = HashMap::new();
        for (side, exes) in
            [("agent", &mut agent_exes), ("server", &mut server_exes)]
        {
            let hlo = entry
                .at(&[side, "hlo"])
                .and_then(|h| match h {
                    Json::Obj(kv) => Some(kv),
                    _ => None,
                })
                .with_context(|| format!("{side}.hlo missing"))?;
            for (b, file) in hlo {
                let batch: usize = b.parse().context("batch key")?;
                let file = file.as_str().context("hlo file name")?;
                exes.insert(batch, reg.executable(file)?);
            }
        }
        let flops = |side: &str| {
            entry.at(&[side, "flops"]).and_then(Json::as_f64).unwrap_or(0.0)
        };
        Ok(CoModel {
            name: name.to_string(),
            agent_weights: WeightStore::load(&reg.dir, entry.get("agent").unwrap())?,
            server_weights: WeightStore::load(&reg.dir, entry.get("server").unwrap())?,
            agent_flops: flops("agent"),
            server_flops: flops("server"),
            dims,
            agent_exes,
            server_exes,
        })
    }

    /// Largest compiled batch size <= n (falling back to 1).
    pub fn pick_batch(&self, available: &HashMap<usize, Rc<Executable>>, n: usize) -> usize {
        available
            .keys()
            .copied()
            .filter(|b| *b <= n.max(1))
            .max()
            .unwrap_or(1)
    }

    pub fn agent_batches(&self) -> Vec<usize> {
        let mut b: Vec<usize> = self.agent_exes.keys().copied().collect();
        b.sort();
        b
    }

    /// Agent stage: images -> embeddings, with the encoder weights
    /// quantized at (bits, scheme). `inputs` holds `n` samples flattened;
    /// requests are chunked over the compiled batch sizes.
    pub fn encode(
        &mut self,
        inputs: &[f32],
        n: usize,
        bits: u32,
        scheme: Scheme,
    ) -> Result<Vec<f32>> {
        let in_len = self.dims.input_len();
        anyhow::ensure!(inputs.len() == n * in_len, "input length mismatch");
        let weights = self.agent_weights.quantized(bits, scheme)?;
        let mut out = Vec::with_capacity(n * self.dims.emb_len());
        let mut i = 0;
        while i < n {
            let batch = self.pick_batch(&self.agent_exes, n - i);
            let exe = self.agent_exes.get(&batch).context("no batch exe")?.clone();
            let mut shape = vec![batch];
            shape.extend(&self.dims.input);
            let input = literal_f32(&inputs[i * in_len..(i + batch) * in_len], &shape)?;
            let mut args: Vec<&xla::Literal> = Vec::with_capacity(1 + weights.literals.len());
            args.push(&input);
            for w in &weights.literals {
                args.push(w);
            }
            out.extend(exe.run_f32(&args)?);
            i += batch;
        }
        Ok(out)
    }

    /// Server stage: embeddings -> greedy-decoded token ids per sample.
    pub fn decode(&mut self, embs: &[f32], n: usize) -> Result<Vec<Vec<i32>>> {
        let emb_len = self.dims.emb_len();
        anyhow::ensure!(embs.len() == n * emb_len, "embedding length mismatch");
        let weights = self.server_weights.full_precision()?;
        let mut out = Vec::with_capacity(n);
        let mut i = 0;
        while i < n {
            let batch = self.pick_batch(&self.server_exes, n - i);
            let exe = self.server_exes.get(&batch).context("no batch exe")?.clone();
            let shape = vec![batch, self.dims.emb_tokens, self.dims.d_model];
            let input = literal_f32(&embs[i * emb_len..(i + batch) * emb_len], &shape)?;
            let mut args: Vec<&xla::Literal> = Vec::with_capacity(1 + weights.literals.len());
            args.push(&input);
            for w in &weights.literals {
                args.push(w);
            }
            let toks = exe.run_i32(&args)?;
            for b in 0..batch {
                out.push(toks[b * self.dims.max_len..(b + 1) * self.dims.max_len].to_vec());
            }
            i += batch;
        }
        Ok(out)
    }

    /// Full co-inference for a batch of samples.
    pub fn infer(
        &mut self,
        inputs: &[f32],
        n: usize,
        bits: u32,
        scheme: Scheme,
    ) -> Result<Vec<Vec<i32>>> {
        let embs = self.encode(inputs, n, bits, scheme)?;
        self.decode(&embs, n)
    }
}

/// The FCDNN-16 autoencoder (Fig. 3).
pub struct Fcdnn {
    exe: Rc<Executable>,
    pub weights: WeightStore,
    pub batch: usize,
    pub flops: f64,
}

impl Fcdnn {
    pub fn load(reg: &Registry) -> Result<Fcdnn> {
        let entry = reg.model("fcdnn16")?.clone();
        let batch = entry.get("batch").and_then(Json::as_usize).context("batch")?;
        let hlo = entry
            .at(&["hlo", &batch.to_string()])
            .and_then(Json::as_str)
            .context("fcdnn hlo")?;
        Ok(Fcdnn {
            exe: reg.executable(hlo)?,
            weights: WeightStore::load(&reg.dir, &entry)?,
            batch,
            flops: entry.get("flops").and_then(Json::as_f64).unwrap_or(0.0),
        })
    }

    /// Forward a full batch with externally supplied weight blob (e.g.
    /// quantized variants for the distortion study).
    pub fn forward_with_blob(&self, xs: &[f32], blob: &[f32]) -> Result<Vec<f32>> {
        anyhow::ensure!(xs.len() == self.batch * 784);
        anyhow::ensure!(blob.len() == self.weights.n_params());
        let input = literal_f32(xs, &[self.batch, 784])?;
        let lits: Vec<xla::Literal> = self
            .weights
            .specs
            .iter()
            .map(|s| literal_f32(&blob[s.offset..s.offset + s.len], &s.shape))
            .collect::<Result<_>>()?;
        let mut args: Vec<&xla::Literal> = Vec::with_capacity(1 + lits.len());
        args.push(&input);
        for l in &lits {
            args.push(l);
        }
        self.exe.run_f32(&args)
    }

    pub fn forward(&mut self, xs: &[f32]) -> Result<Vec<f32>> {
        let blob = self.weights.blob.clone();
        self.forward_with_blob(xs, &blob)
    }
}

/// The standalone Pallas fake-quant modules (Rust-vs-XLA cross-check).
pub struct QuantKernel {
    uniform: Rc<Executable>,
    pot: Rc<Executable>,
    pub rows: usize,
}

impl QuantKernel {
    pub fn load(reg: &Registry) -> Result<QuantKernel> {
        let q = reg.manifest.get("quant").context("quant entry")?;
        Ok(QuantKernel {
            uniform: reg.executable(q.get("uniform").and_then(Json::as_str).context("uniform")?)?,
            pot: reg.executable(q.get("pot").and_then(Json::as_str).context("pot")?)?,
            rows: q.get("rows").and_then(Json::as_usize).context("rows")?,
        })
    }

    pub fn buf_len(&self) -> usize {
        self.rows * 128
    }

    pub fn uniform(&self, buf: &[f32], step: f32) -> Result<Vec<f32>> {
        anyhow::ensure!(buf.len() == self.buf_len());
        let w = literal_f32(buf, &[self.rows, 128])?;
        let s = literal_scalar(step)?;
        self.uniform.run_f32(&[&w, &s])
    }

    pub fn pot(&self, buf: &[f32], emin: f32, emax: f32) -> Result<Vec<f32>> {
        anyhow::ensure!(buf.len() == self.buf_len());
        let w = literal_f32(buf, &[self.rows, 128])?;
        let lo = literal_scalar(emin)?;
        let hi = literal_scalar(emax)?;
        self.pot.run_f32(&[&w, &lo, &hi])
    }
}
