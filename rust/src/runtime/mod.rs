//! PJRT runtime: loads the AOT artifacts `make artifacts` produced and
//! executes them on the request path — Python never runs here.
//!
//! * [`client`] — thin wrapper over the `xla` crate: HLO text →
//!   `XlaComputation` → compiled executable, with tuple unwrapping.
//! * [`weights`] — weight blobs + per-bitwidth quantized literal caches.
//! * [`artifact`] — manifest-driven registry of every shipped module.
//! * [`executor`] — model-level API: encode (agent stage) / decode (server
//!   stage) / fcdnn forward, over cached executables and weight literals.

pub mod artifact;
pub mod client;
pub mod executor;
pub mod weights;

pub use artifact::Registry;
pub use client::{Executable, Runtime};
pub use executor::CoModel;
