//! Weight store: trained parameter blobs + quantized-literal caches.
//!
//! The AOT modules take every parameter as a runtime input, so quantizing
//! at a new bit-width is a pure host-side transform: quantize the blob
//! (sign-preserving, §II-C), slice it per tensor, and build PJRT literals.
//! Results are cached per (bits, scheme) — the serving hot path reuses the
//! literals for every request at that operating point.

use crate::quant::{self, Scheme};
use crate::runtime::client::literal_f32;
use crate::theory::expdist::ExponentialModel;
use crate::util::json::Json;
use anyhow::{Context, Result};
use std::collections::HashMap;
use std::path::Path;
use std::rc::Rc;

/// One tensor's metadata within the blob.
#[derive(Debug, Clone)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub len: usize,
}

/// Cached quantized view of the blob.
pub struct QuantizedWeights {
    pub literals: Vec<Rc<xla::Literal>>,
    /// total L1 parameter distortion vs full precision (eq. 15)
    pub l1_distortion: f64,
    /// per-parameter mean |w - ŵ| (the D of §IV)
    pub mean_abs_distortion: f64,
}

pub struct WeightStore {
    pub specs: Vec<TensorSpec>,
    pub blob: Vec<f32>,
    /// MLE-fitted exponential parameter (manifest value, python-fitted)
    pub lambda: f64,
    cache: HashMap<(u32, Scheme), Rc<QuantizedWeights>>,
    /// cache of the full-precision literals (bits = 0 sentinel)
    full: Option<Rc<QuantizedWeights>>,
}

impl WeightStore {
    /// Load from a manifest model-side entry ({"weights", "params",
    /// "lambda", ...}).
    pub fn load(artifacts: &Path, entry: &Json) -> Result<WeightStore> {
        let file = entry
            .get("weights")
            .and_then(Json::as_str)
            .context("weights file missing in manifest")?;
        let bytes = std::fs::read(artifacts.join(file))
            .with_context(|| format!("reading {file}"))?;
        let blob: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        let params = entry
            .get("params")
            .and_then(Json::as_arr)
            .context("params missing")?;
        let mut specs = Vec::with_capacity(params.len());
        let mut offset = 0usize;
        for p in params {
            let name = p.get("name").and_then(Json::as_str).context("param name")?;
            let shape: Vec<usize> = p
                .get("shape")
                .and_then(Json::as_arr)
                .context("param shape")?
                .iter()
                .filter_map(Json::as_usize)
                .collect();
            let len: usize = shape.iter().product();
            specs.push(TensorSpec { name: name.to_string(), shape, offset, len });
            offset += len;
        }
        anyhow::ensure!(
            offset == blob.len(),
            "weight blob {} has {} f32s, specs expect {}",
            file,
            blob.len(),
            offset
        );
        let lambda = entry
            .get("lambda")
            .and_then(Json::as_f64)
            .unwrap_or_else(|| ExponentialModel::fit_weights(&blob).lambda);
        Ok(WeightStore { specs, blob, lambda, cache: HashMap::new(), full: None })
    }

    /// Build from raw parts (tests).
    pub fn from_parts(specs: Vec<(String, Vec<usize>)>, blob: Vec<f32>) -> WeightStore {
        let mut out = Vec::new();
        let mut offset = 0;
        for (name, shape) in specs {
            let len: usize = shape.iter().product();
            out.push(TensorSpec { name, shape, offset, len });
            offset += len;
        }
        assert_eq!(offset, blob.len());
        let lambda = ExponentialModel::fit_weights(&blob).lambda;
        WeightStore { specs: out, blob, lambda, cache: HashMap::new(), full: None }
    }

    pub fn n_params(&self) -> usize {
        self.blob.len()
    }

    pub fn tensor(&self, i: usize) -> &[f32] {
        let s = &self.specs[i];
        &self.blob[s.offset..s.offset + s.len]
    }

    fn build_literals(&self, data: &[f32]) -> Result<Vec<Rc<xla::Literal>>> {
        self.specs
            .iter()
            .map(|s| {
                literal_f32(&data[s.offset..s.offset + s.len], &s.shape).map(Rc::new)
            })
            .collect()
    }

    /// Full-precision literals (cached).
    pub fn full_precision(&mut self) -> Result<Rc<QuantizedWeights>> {
        if let Some(f) = &self.full {
            return Ok(f.clone());
        }
        let literals = self.build_literals(&self.blob)?;
        let qw = Rc::new(QuantizedWeights {
            literals,
            l1_distortion: 0.0,
            mean_abs_distortion: 0.0,
        });
        self.full = Some(qw.clone());
        Ok(qw)
    }

    /// Quantized literals at (bits, scheme), cached. `bits >= full_bits`
    /// short-circuits to full precision.
    pub fn quantized(&mut self, bits: u32, scheme: Scheme) -> Result<Rc<QuantizedWeights>> {
        if bits >= 32 {
            return self.full_precision();
        }
        if let Some(q) = self.cache.get(&(bits, scheme)) {
            return Ok(q.clone());
        }
        let qblob = quant::quantize_magnitudes(&self.blob, bits, scheme);
        let literals = self.build_literals(&qblob)?;
        let l1 = quant::total_l1_distortion(&self.blob, &qblob);
        let qw = Rc::new(QuantizedWeights {
            literals,
            l1_distortion: l1,
            mean_abs_distortion: l1 / self.blob.len() as f64,
        });
        self.cache.insert((bits, scheme), qw.clone());
        Ok(qw)
    }

    /// Quantize without literal construction (distortion studies).
    pub fn quantized_blob(&self, bits: u32, scheme: Scheme) -> Vec<f32> {
        quant::quantize_magnitudes(&self.blob, bits, scheme)
    }

    pub fn cached_points(&self) -> usize {
        self.cache.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::parse;
    use crate::util::rng::Rng;

    fn store() -> WeightStore {
        let mut rng = Rng::new(0);
        let blob: Vec<f32> = (0..256 + 16).map(|_| 0.1 * rng.normal() as f32).collect();
        WeightStore::from_parts(vec![("w".into(), vec![16, 16]), ("b".into(), vec![16])], blob)
    }

    #[test]
    fn tensor_slicing_respects_offsets() {
        let s = store();
        assert_eq!(s.tensor(0).len(), 256);
        assert_eq!(s.tensor(1).len(), 16);
        assert_eq!(s.n_params(), 272);
        assert_eq!(s.tensor(1)[0], s.blob[256]);
    }

    #[test]
    fn quantized_blob_distortion_shrinks_with_bits() {
        let s = store();
        let d4 = crate::quant::total_l1_distortion(&s.blob, &s.quantized_blob(4, Scheme::Uniform));
        let d8 = crate::quant::total_l1_distortion(&s.blob, &s.quantized_blob(8, Scheme::Uniform));
        assert!(d8 < d4);
    }

    #[test]
    fn cache_returns_same_rc() {
        let mut s = store();
        let a = s.quantized(5, Scheme::Uniform).unwrap();
        let b = s.quantized(5, Scheme::Uniform).unwrap();
        assert!(Rc::ptr_eq(&a, &b));
        assert_eq!(s.cached_points(), 1);
        // different scheme = different cache slot
        s.quantized(5, Scheme::Pot).unwrap();
        assert_eq!(s.cached_points(), 2);
        // >= 32 bits short-circuits to full precision (no distortion)
        let f = s.quantized(32, Scheme::Uniform).unwrap();
        assert_eq!(f.l1_distortion, 0.0);
        assert_eq!(s.cached_points(), 2);
    }

    #[test]
    fn manifest_size_mismatch_rejected() {
        let dir = std::env::temp_dir().join(format!("qaci-ws-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        // blob with 8 f32s but spec demanding 16
        std::fs::write(dir.join("w.bin"), [0u8; 32]).unwrap();
        let entry = parse(
            r#"{"weights":"w.bin","params":[{"name":"w","shape":[4,4]}],"lambda":10.0}"#,
        )
        .unwrap();
        assert!(WeightStore::load(&dir, &entry).is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn missing_weight_file_rejected() {
        let dir = std::env::temp_dir().join(format!("qaci-ws2-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let entry = parse(
            r#"{"weights":"nope.bin","params":[{"name":"w","shape":[2]}]}"#,
        )
        .unwrap();
        assert!(WeightStore::load(&dir, &entry).is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
