//! Wireless link substrate: the 5 GHz WLAN between agent and server that
//! carries embeddings up and results down (paper Fig. 1 / testbed §VI).
//!
//! The paper's optimization treats computation delay/energy only (LAIM
//! inference is computation-dominated); the link here adds end-to-end
//! realism to the coordinator and is *excluded* from the T/E constraint
//! math, matching the paper. Deterministic jitter keeps runs reproducible.
//!
//! The fleet extension ([`MultiAccessChannel`]) divides one medium across
//! N agents with TDMA/OFDMA-style airtime shares; there the uplink time
//! *does* enter the fleet allocator's per-agent delay budget (see
//! [`crate::opt::fleet`]), because a congested shared medium is no longer
//! negligible against the computation delay.

use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct Channel {
    /// nominal goodput [bits/s]
    pub rate_bps: f64,
    /// fixed per-message latency [s] (MAC + propagation + serialization)
    pub base_latency_s: f64,
    /// multiplicative jitter half-width (0.1 => ±10% rate variation)
    pub jitter: f64,
    rng: Rng,
}

impl Channel {
    /// Stable 5 GHz WLAN, per the testbed description: ~400 Mbps goodput,
    /// ~2 ms base latency, mild jitter.
    pub fn wlan_5ghz(seed: u64) -> Channel {
        Channel {
            rate_bps: 400e6,
            base_latency_s: 2e-3,
            jitter: 0.10,
            rng: Rng::new(seed),
        }
    }

    /// Ideal infinite-rate link (isolates computation in benches).
    pub fn ideal() -> Channel {
        Channel {
            rate_bps: f64::INFINITY,
            base_latency_s: 0.0,
            jitter: 0.0,
            rng: Rng::new(0),
        }
    }

    /// Arbitrary link parameters (fleet subchannels, tests).
    pub fn custom(rate_bps: f64, base_latency_s: f64, jitter: f64, seed: u64) -> Channel {
        assert!(rate_bps >= 0.0 && base_latency_s >= 0.0 && (0.0..1.0).contains(&jitter));
        Channel { rate_bps, base_latency_s, jitter, rng: Rng::new(seed) }
    }

    /// Simulated transmission time for a payload of `bytes`.
    pub fn transmit_s(&mut self, bytes: usize) -> f64 {
        if self.rate_bps.is_infinite() {
            return self.base_latency_s;
        }
        let wobble = 1.0 + self.jitter * (2.0 * self.rng.f64() - 1.0);
        self.base_latency_s + (bytes as f64 * 8.0) / (self.rate_bps * wobble)
    }

    /// Embedding payload size: tokens × d_model × 4 bytes (f32 features).
    pub fn embedding_bytes(tokens: usize, d_model: usize) -> usize {
        tokens * d_model * 4
    }
}

/// One wireless medium shared by a fleet of N agents.
///
/// Multi-access is modeled as airtime shares α_i ∈ [0, 1] with
/// Σ α_i ≤ 1 (TDMA slot fractions / OFDMA subcarrier fractions): agent i
/// sees an effective goodput α_i · g_i · R, where g_i ∈ (0, 1] is the
/// agent's **channel gain** (radio quality / path loss; 1.0 = nominal,
/// the homogeneous default set by [`Self::with_gains`]). Transmission
/// delay is strictly decreasing in share and gain, and an agent with
/// α_i = 0 cannot transmit at all. Base MAC latency is per-message and
/// share-independent.
#[derive(Debug, Clone)]
pub struct MultiAccessChannel {
    /// total medium goodput R [bits/s]
    pub total_rate_bps: f64,
    /// fixed per-message latency [s]
    pub base_latency_s: f64,
    /// multiplicative jitter half-width (applied per transmission)
    pub jitter: f64,
    shares: Vec<f64>,
    /// per-agent channel gain g_i ∈ (0, 1]
    gains: Vec<f64>,
    rng: Rng,
}

impl MultiAccessChannel {
    /// Validates the share vector: every α_i ≥ 0 and Σ α_i ≤ 1 (+ulp).
    pub fn new(
        total_rate_bps: f64,
        base_latency_s: f64,
        jitter: f64,
        shares: Vec<f64>,
        seed: u64,
    ) -> MultiAccessChannel {
        assert!(!shares.is_empty(), "at least one agent");
        assert!(
            shares.iter().all(|&a| (0.0..=1.0).contains(&a)),
            "airtime shares must lie in [0, 1]: {shares:?}"
        );
        let total: f64 = shares.iter().sum();
        assert!(
            total <= 1.0 + 1e-9,
            "airtime shares must sum to <= 1, got {total} ({shares:?})"
        );
        let gains = vec![1.0; shares.len()];
        MultiAccessChannel {
            total_rate_bps,
            base_latency_s,
            jitter,
            shares,
            gains,
            rng: Rng::new(seed),
        }
    }

    /// Set per-agent channel gains (heterogeneous radios); every gain
    /// must lie in (0, 1]. Construction defaults every gain to 1.0.
    pub fn with_gains(mut self, gains: Vec<f64>) -> MultiAccessChannel {
        assert_eq!(gains.len(), self.shares.len(), "one gain per agent");
        assert!(
            gains.iter().all(|&g| g > 0.0 && g <= 1.0),
            "channel gains must lie in (0, 1]: {gains:?}"
        );
        self.gains = gains;
        self
    }

    pub fn gain(&self, agent: usize) -> f64 {
        self.gains[agent]
    }

    /// The testbed WLAN (400 Mbps, 2 ms, ±10%) split across the fleet.
    pub fn wlan_5ghz(shares: Vec<f64>, seed: u64) -> MultiAccessChannel {
        MultiAccessChannel::new(400e6, 2e-3, 0.10, shares, seed)
    }

    /// Infinite-rate medium for n agents (isolates computation).
    pub fn ideal(n: usize) -> MultiAccessChannel {
        MultiAccessChannel::new(f64::INFINITY, 0.0, 0.0, Self::equal_shares(n), 0)
    }

    /// The canonical uniform split: α_i = 1/n.
    pub fn equal_shares(n: usize) -> Vec<f64> {
        assert!(n > 0);
        vec![1.0 / n as f64; n]
    }

    pub fn n_agents(&self) -> usize {
        self.shares.len()
    }

    pub fn share(&self, agent: usize) -> f64 {
        self.shares[agent]
    }

    pub fn shares(&self) -> &[f64] {
        &self.shares
    }

    /// Replace the share vector (fleet re-allocation); same validation as
    /// construction.
    pub fn set_shares(&mut self, shares: Vec<f64>) {
        assert_eq!(shares.len(), self.shares.len(), "fleet size is fixed");
        assert!(shares.iter().all(|&a| (0.0..=1.0).contains(&a)));
        assert!(shares.iter().sum::<f64>() <= 1.0 + 1e-9);
        self.shares = shares;
    }

    /// Deterministic transmission time at a given share — the quantity the
    /// fleet allocator budgets against (no jitter).
    pub fn nominal_transmit_s(
        total_rate_bps: f64,
        base_latency_s: f64,
        share: f64,
        bytes: usize,
    ) -> f64 {
        if total_rate_bps.is_infinite() {
            return base_latency_s;
        }
        if share <= 0.0 {
            return f64::INFINITY; // the agent cannot transmit at all
        }
        base_latency_s + (bytes as f64 * 8.0) / (total_rate_bps * share)
    }

    /// Simulated (jittered) transmission time for `agent` at its share
    /// and channel gain.
    pub fn transmit_s(&mut self, agent: usize, bytes: usize) -> f64 {
        let share = self.shares[agent];
        if self.total_rate_bps.is_infinite() {
            return self.base_latency_s;
        }
        if share <= 0.0 {
            return f64::INFINITY;
        }
        let wobble = 1.0 + self.jitter * (2.0 * self.rng.f64() - 1.0);
        let rate = self.total_rate_bps * self.gains[agent];
        self.base_latency_s + (bytes as f64 * 8.0) / (rate * share * wobble)
    }

    /// Per-agent single-link view (rate α_i · g_i · R): lets fleet
    /// components reuse everything written against [`Channel`].
    pub fn subchannel(&self, agent: usize, seed: u64) -> Channel {
        Channel::custom(
            self.total_rate_bps * self.gains[agent] * self.shares[agent],
            self.base_latency_s,
            self.jitter,
            seed,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transmit_time_scales_with_size() {
        let mut ch = Channel::wlan_5ghz(1);
        let t1 = ch.transmit_s(10_000);
        let t2 = ch.transmit_s(10_000_000);
        assert!(t2 > t1);
        // 10 MB over ~400 Mbps ≈ 0.2 s
        assert!(t2 > 0.1 && t2 < 0.4, "{t2}");
    }

    #[test]
    fn ideal_link_is_free() {
        let mut ch = Channel::ideal();
        assert_eq!(ch.transmit_s(1 << 30), 0.0);
    }

    #[test]
    fn jitter_bounded() {
        let mut ch = Channel::wlan_5ghz(2);
        let nominal = 8.0 * 1e6 / ch.rate_bps + ch.base_latency_s;
        for _ in 0..200 {
            let t = ch.transmit_s(1_000_000);
            assert!(t > nominal * 0.85 && t < nominal * 1.25, "{t} vs {nominal}");
        }
    }

    #[test]
    fn embedding_payload_matches_blip2ish() {
        // 16 query tokens × 128 dims × 4 B = 8 KiB
        assert_eq!(Channel::embedding_bytes(16, 128), 8192);
    }

    #[test]
    #[should_panic(expected = "sum to <= 1")]
    fn oversubscribed_shares_rejected() {
        MultiAccessChannel::wlan_5ghz(vec![0.6, 0.6], 1);
    }

    #[test]
    #[should_panic(expected = "lie in [0, 1]")]
    fn negative_share_rejected() {
        MultiAccessChannel::wlan_5ghz(vec![0.5, -0.1], 1);
    }

    #[test]
    fn equal_shares_sum_to_one() {
        for n in [1usize, 3, 7, 64] {
            let s = MultiAccessChannel::equal_shares(n);
            assert_eq!(s.len(), n);
            assert!((s.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn delay_is_monotone_decreasing_in_share() {
        let bytes = 1 << 20;
        let mut prev = f64::INFINITY;
        for share in [0.05, 0.1, 0.25, 0.5, 1.0] {
            let t = MultiAccessChannel::nominal_transmit_s(400e6, 2e-3, share, bytes);
            assert!(t < prev, "share {share}: {t} !< {prev}");
            prev = t;
        }
        // full share reproduces the single-agent link exactly
        let full = MultiAccessChannel::nominal_transmit_s(400e6, 2e-3, 1.0, bytes);
        assert!((full - (2e-3 + (bytes as f64 * 8.0) / 400e6)).abs() < 1e-12);
    }

    #[test]
    fn zero_share_cannot_transmit() {
        let mut ch = MultiAccessChannel::wlan_5ghz(vec![0.0, 1.0], 3);
        assert!(ch.transmit_s(0, 1000).is_infinite());
        assert!(ch.transmit_s(1, 1000).is_finite());
        assert!(MultiAccessChannel::nominal_transmit_s(400e6, 0.0, 0.0, 1000)
            .is_infinite());
    }

    #[test]
    fn jittered_transmit_brackets_nominal() {
        let mut ch = MultiAccessChannel::wlan_5ghz(MultiAccessChannel::equal_shares(4), 9);
        let nominal = MultiAccessChannel::nominal_transmit_s(400e6, 2e-3, 0.25, 1 << 20);
        for _ in 0..200 {
            let t = ch.transmit_s(2, 1 << 20);
            assert!(t > nominal * 0.85 && t < nominal * 1.25, "{t} vs {nominal}");
        }
    }

    #[test]
    fn ideal_medium_is_free_for_everyone() {
        let mut ch = MultiAccessChannel::ideal(8);
        for agent in 0..8 {
            assert_eq!(ch.transmit_s(agent, 1 << 30), 0.0);
        }
    }

    #[test]
    fn subchannel_sees_scaled_rate() {
        let ch = MultiAccessChannel::wlan_5ghz(vec![0.25, 0.75], 5);
        let sub = ch.subchannel(0, 11);
        assert!((sub.rate_bps - 100e6).abs() < 1.0);
        assert_eq!(sub.base_latency_s, 2e-3);
    }

    #[test]
    fn channel_gain_scales_goodput() {
        // same share, half the gain => strictly slower; gain 1.0 is the
        // exact homogeneous behavior (bit-for-bit, no epsilon)
        let mut nominal = MultiAccessChannel::new(400e6, 2e-3, 0.0, vec![0.5, 0.5], 3);
        let mut faded = MultiAccessChannel::new(400e6, 2e-3, 0.0, vec![0.5, 0.5], 3)
            .with_gains(vec![1.0, 0.5]);
        let t_full = nominal.transmit_s(1, 1 << 20);
        let t_half = faded.transmit_s(1, 1 << 20);
        assert!((t_half - 2e-3) > (t_full - 2e-3) * 1.99, "{t_half} vs {t_full}");
        assert_eq!(nominal.transmit_s(0, 1 << 20), faded.transmit_s(0, 1 << 20));
        let sub = faded.subchannel(1, 7);
        assert!((sub.rate_bps - 400e6 * 0.5 * 0.5).abs() < 1.0);
    }

    #[test]
    fn bad_gains_rejected() {
        for gains in [vec![1.0], vec![0.0, 1.0], vec![1.5, 1.0], vec![f64::NAN, 1.0]] {
            let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                MultiAccessChannel::wlan_5ghz(vec![0.5, 0.5], 1).with_gains(gains.clone());
            }));
            assert!(res.is_err(), "{gains:?} must be rejected");
        }
    }

    #[test]
    fn set_shares_revalidates() {
        let mut ch = MultiAccessChannel::wlan_5ghz(vec![0.5, 0.5], 1);
        ch.set_shares(vec![0.9, 0.1]);
        assert_eq!(ch.share(0), 0.9);
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            ch.set_shares(vec![0.9, 0.9]);
        }));
        assert!(res.is_err(), "oversubscription must be rejected");
    }

    #[test]
    fn prop_set_shares_preserves_partition_invariants() {
        // property: any valid re-allocation (the online churn path calls
        // set_shares constantly) leaves the medium a valid partition —
        // every α in [0, 1], Σ α ≤ 1, and the shares readable back intact
        use crate::util::prop::forall;
        forall(
            "set_shares keeps a valid airtime partition",
            150,
            |r| {
                let n = 1 + r.below(8);
                let raw: Vec<f64> = (0..n).map(|_| r.range(0.0, 1.0)).collect();
                let total: f64 = raw.iter().sum();
                // scale into [0, 1] with random slack so Σ < 1 and Σ = 1
                // both occur
                let scale = r.range(0.1, 1.0) / total.max(1e-9);
                (raw.iter().map(|x| x * scale).collect::<Vec<f64>>(), n)
            },
            |(shares, n)| {
                let mut ch = MultiAccessChannel::wlan_5ghz(MultiAccessChannel::equal_shares(*n), 4);
                ch.set_shares(shares.clone());
                let back = ch.shares();
                if back != shares.as_slice() {
                    return Err(format!("shares mangled: {back:?}"));
                }
                let total: f64 = back.iter().sum();
                if !back.iter().all(|&a| (0.0..=1.0).contains(&a)) || total > 1.0 + 1e-9 {
                    return Err(format!("invalid partition: {back:?} (Σ={total})"));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_transmit_monotone_decreasing_in_share() {
        // property: more airtime never slows a transmission — nominal and
        // jittered times are (strictly, for finite rate) decreasing in α
        use crate::util::prop::forall;
        forall(
            "transmit_s monotone decreasing in share",
            200,
            |r| {
                let lo = r.range(1e-3, 0.5);
                let hi = lo + r.range(1e-3, 0.5);
                (
                    r.range(1e6, 1e9),          // rate
                    r.range(0.0, 0.01),         // base latency
                    1 + r.below(10_000_000),    // bytes
                    lo,
                    hi.min(1.0),
                )
            },
            |&(rate, base, bytes, lo, hi)| {
                let t_lo = MultiAccessChannel::nominal_transmit_s(rate, base, lo, bytes);
                let t_hi = MultiAccessChannel::nominal_transmit_s(rate, base, hi, bytes);
                if t_hi >= t_lo {
                    return Err(format!("nominal not decreasing: {t_hi} >= {t_lo}"));
                }
                // the jittered path preserves the ordering per-draw: with
                // the same seed both agents see the same wobble sequence
                let mut a = MultiAccessChannel::new(rate, base, 0.1, vec![lo, 0.0], 9);
                let mut b = MultiAccessChannel::new(rate, base, 0.1, vec![hi, 0.0], 9);
                for _ in 0..5 {
                    if a.transmit_s(0, bytes) <= b.transmit_s(0, bytes) {
                        return Err("jittered not decreasing".into());
                    }
                }
                Ok(())
            },
        );
    }
}
