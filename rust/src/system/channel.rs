//! Wireless link substrate: the 5 GHz WLAN between agent and server that
//! carries embeddings up and results down (paper Fig. 1 / testbed §VI).
//!
//! The paper's optimization treats computation delay/energy only (LAIM
//! inference is computation-dominated); the link here adds end-to-end
//! realism to the coordinator and is *excluded* from the T/E constraint
//! math, matching the paper. Deterministic jitter keeps runs reproducible.

use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct Channel {
    /// nominal goodput [bits/s]
    pub rate_bps: f64,
    /// fixed per-message latency [s] (MAC + propagation + serialization)
    pub base_latency_s: f64,
    /// multiplicative jitter half-width (0.1 => ±10% rate variation)
    pub jitter: f64,
    rng: Rng,
}

impl Channel {
    /// Stable 5 GHz WLAN, per the testbed description: ~400 Mbps goodput,
    /// ~2 ms base latency, mild jitter.
    pub fn wlan_5ghz(seed: u64) -> Channel {
        Channel {
            rate_bps: 400e6,
            base_latency_s: 2e-3,
            jitter: 0.10,
            rng: Rng::new(seed),
        }
    }

    /// Ideal infinite-rate link (isolates computation in benches).
    pub fn ideal() -> Channel {
        Channel {
            rate_bps: f64::INFINITY,
            base_latency_s: 0.0,
            jitter: 0.0,
            rng: Rng::new(0),
        }
    }

    /// Simulated transmission time for a payload of `bytes`.
    pub fn transmit_s(&mut self, bytes: usize) -> f64 {
        if self.rate_bps.is_infinite() {
            return self.base_latency_s;
        }
        let wobble = 1.0 + self.jitter * (2.0 * self.rng.f64() - 1.0);
        self.base_latency_s + (bytes as f64 * 8.0) / (self.rate_bps * wobble)
    }

    /// Embedding payload size: tokens × d_model × 4 bytes (f32 features).
    pub fn embedding_bytes(tokens: usize, d_model: usize) -> usize {
        tokens * d_model * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transmit_time_scales_with_size() {
        let mut ch = Channel::wlan_5ghz(1);
        let t1 = ch.transmit_s(10_000);
        let t2 = ch.transmit_s(10_000_000);
        assert!(t2 > t1);
        // 10 MB over ~400 Mbps ≈ 0.2 s
        assert!(t2 > 0.1 && t2 < 0.4, "{t2}");
    }

    #[test]
    fn ideal_link_is_free() {
        let mut ch = Channel::ideal();
        assert_eq!(ch.transmit_s(1 << 30), 0.0);
    }

    #[test]
    fn jitter_bounded() {
        let mut ch = Channel::wlan_5ghz(2);
        let nominal = 8.0 * 1e6 / ch.rate_bps + ch.base_latency_s;
        for _ in 0..200 {
            let t = ch.transmit_s(1_000_000);
            assert!(t > nominal * 0.85 && t < nominal * 1.25, "{t} vs {nominal}");
        }
    }

    #[test]
    fn embedding_payload_matches_blip2ish() {
        // 16 query tokens × 128 dims × 4 B = 8 KiB
        assert_eq!(Channel::embedding_bytes(16, 128), 8192);
    }
}
