//! Computation delay model (paper eq. 4, 5, 8).

use super::platform::Platform;

/// On-agent inference delay t(b̂, f) = b̂ N / (b f c)  (eq. 4).
pub fn agent_delay(p: &Platform, b_hat: f64, f: f64) -> f64 {
    assert!(f > 0.0, "device frequency must be positive");
    p.agent_cycles(b_hat) / f
}

/// On-server inference delay t̃(f̃) = Ñ / (f̃ c̃)  (eq. 5).
pub fn server_delay(p: &Platform, f_tilde: f64) -> f64 {
    assert!(f_tilde > 0.0, "server frequency must be positive");
    p.server_cycles() / f_tilde
}

/// Total computation delay T(b̂, f, f̃)  (eq. 8).
pub fn total_delay(p: &Platform, b_hat: f64, f: f64, f_tilde: f64) -> f64 {
    agent_delay(p, b_hat, f) + server_delay(p, f_tilde)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;

    #[test]
    fn closed_form_example() {
        let p = Platform::paper_blip2();
        // b̂=8: workload = 8/32 * 160.098 GFLOP = 40.0245 GFLOP
        // at f=2GHz, c=32 -> 64 GFLOP/s -> 0.6254 s
        let t = agent_delay(&p, 8.0, 2.0e9);
        assert!((t - 8.0 * 0.30 * 533.66e9 / (32.0 * 2.0e9 * 32.0)).abs() < 1e-9);
    }

    #[test]
    fn delay_monotonicity() {
        let p = Platform::paper_blip2();
        forall(
            "delay falls with f, grows with b̂",
            200,
            |r| (r.range(1.0, 16.0), r.range(1e8, 2e9), r.range(1e8, 1e10)),
            |&(b, f, ft)| {
                let t = total_delay(&p, b, f, ft);
                if total_delay(&p, b + 1.0, f, ft) <= t {
                    return Err("not increasing in b̂".into());
                }
                if total_delay(&p, b, f * 1.1, ft) >= t {
                    return Err("not decreasing in f".into());
                }
                if total_delay(&p, b, f, ft * 1.1) >= t {
                    return Err("not decreasing in f̃".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn additivity() {
        let p = Platform::paper_git();
        let (b, f, ft) = (6.0, 1.5e9, 8e9);
        assert_eq!(total_delay(&p, b, f, ft), agent_delay(&p, b, f) + server_delay(&p, ft));
    }
}
