//! DVFS governors: continuous frequency control (the simulation setting of
//! §VI-C) and coarse profile-quantized control (the testbed setting of
//! Table I, where the Jetson only exposes low/medium/high operating
//! points).

/// Frequency governor for one processor.
#[derive(Debug, Clone, PartialEq)]
pub enum Governor {
    /// Any f in (0, f_max] is settable.
    Continuous { f_max: f64 },
    /// Only the listed operating points are settable (ascending order).
    Profiles { points: Vec<f64> },
}

impl Governor {
    /// The Table I testbed device profiles: Jetson AGX Orin coarse
    /// frequency configurations (low / medium / high), in Hz.
    pub fn jetson_profiles() -> Governor {
        Governor::Profiles { points: vec![0.73e9, 1.34e9, 2.2e9] }
    }

    /// Server-side coarse profiles for the testbed runs.
    pub fn server_profiles() -> Governor {
        Governor::Profiles { points: vec![1.8e9, 3.0e9, 4.1e9] }
    }

    pub fn f_max(&self) -> f64 {
        match self {
            Governor::Continuous { f_max } => *f_max,
            Governor::Profiles { points } => *points.last().expect("non-empty"),
        }
    }

    /// Clamp a requested frequency to what the hardware can actually set:
    /// continuous governors clamp to (0, f_max]; profile governors snap
    /// **up** to the next operating point (never slower than requested, so
    /// delay constraints stay satisfied) or the top profile.
    pub fn realize(&self, requested: f64) -> f64 {
        match self {
            Governor::Continuous { f_max } => requested.clamp(f64::MIN_POSITIVE, *f_max),
            Governor::Profiles { points } => {
                for &p in points {
                    if p >= requested {
                        return p;
                    }
                }
                *points.last().expect("non-empty")
            }
        }
    }

    /// Named profile lookup for the testbed bench ("low"/"medium"/"high").
    pub fn profile(&self, name: &str) -> Option<f64> {
        if let Governor::Profiles { points } = self {
            let idx = match name {
                "low" => 0,
                "medium" => points.len() / 2,
                "high" => points.len() - 1,
                _ => return None,
            };
            points.get(idx).copied()
        } else {
            None
        }
    }

    pub fn profile_names(&self) -> Vec<&'static str> {
        match self {
            Governor::Continuous { .. } => vec![],
            Governor::Profiles { .. } => vec!["low", "medium", "high"],
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn continuous_clamps() {
        let g = Governor::Continuous { f_max: 2e9 };
        assert_eq!(g.realize(1e9), 1e9);
        assert_eq!(g.realize(5e9), 2e9);
        assert!(g.realize(-1.0) > 0.0);
    }

    #[test]
    fn profiles_snap_up() {
        let g = Governor::jetson_profiles();
        assert_eq!(g.realize(0.5e9), 0.73e9);
        assert_eq!(g.realize(1.0e9), 1.34e9);
        assert_eq!(g.realize(1.34e9), 1.34e9);
        assert_eq!(g.realize(2.0e9), 2.2e9);
        assert_eq!(g.realize(9.9e9), 2.2e9); // top profile caps
    }

    #[test]
    fn named_profiles() {
        let g = Governor::jetson_profiles();
        assert_eq!(g.profile("low"), Some(0.73e9));
        assert_eq!(g.profile("medium"), Some(1.34e9));
        assert_eq!(g.profile("high"), Some(2.2e9));
        assert_eq!(g.profile("turbo"), None);
        assert!(Governor::Continuous { f_max: 1.0 }.profile("low").is_none());
    }

    #[test]
    fn snap_up_never_increases_delay() {
        // realize() >= requested within range => stage delay can only drop
        let g = Governor::jetson_profiles();
        for req in [0.3e9, 0.9e9, 1.5e9, 2.2e9] {
            assert!(g.realize(req) >= req.min(g.f_max()));
        }
    }
}
