//! Energy consumption model (paper eq. 6, 7, 9).

use super::platform::Platform;

/// On-agent energy e(b̂, f) = η (b̂ N / (b c)) ψ f²  (eq. 6).
pub fn agent_energy(p: &Platform, b_hat: f64, f: f64) -> f64 {
    p.device.pue * p.agent_cycles(b_hat) * p.device.psi * f * f
}

/// On-server energy ẽ(f̃) = η̃ (Ñ / c̃) ψ̃ f̃²  (eq. 7).
pub fn server_energy(p: &Platform, f_tilde: f64) -> f64 {
    p.server.pue * p.server_cycles() * p.server.psi * f_tilde * f_tilde
}

/// Total energy E(b̂, f, f̃)  (eq. 9).
pub fn total_energy(p: &Platform, b_hat: f64, f: f64, f_tilde: f64) -> f64 {
    agent_energy(p, b_hat, f) + server_energy(p, f_tilde)
}

/// Energy of the agent stage expressed via its delay t1 (used by the
/// analytic feasibility oracle): with f = C1/t1,
/// e = η ψ C1 f² = η ψ C1³ / t1².
pub fn agent_energy_of_delay(p: &Platform, b_hat: f64, t1: f64) -> f64 {
    let c1 = p.agent_cycles(b_hat);
    p.device.pue * p.device.psi * c1 * c1 * c1 / (t1 * t1)
}

pub fn server_energy_of_delay(p: &Platform, t2: f64) -> f64 {
    let c2 = p.server_cycles();
    p.server.pue * p.server.psi * c2 * c2 * c2 / (t2 * t2)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::system::delay;
    use crate::util::prop::forall;

    #[test]
    fn paper_magnitude_sanity() {
        // the paper's Fig. 5 budgets are E0 ≈ 2 J; an E0 = 2 J budget must
        // be reachable (some operating point below it) yet binding (max
        // frequencies far exceed it) — exactly the regime the paper sweeps
        let p = Platform::paper_blip2();
        let low = total_energy(&p, 4.0, 0.8e9, 1.5e9);
        let high = total_energy(&p, 8.0, p.device.f_max, p.server.f_max);
        assert!(low < 2.0, "low-point energy {low} should fit E0=2J");
        assert!(high > 2.0, "max-frequency energy {high} should exceed E0=2J");
    }

    #[test]
    fn energy_monotonicity() {
        let p = Platform::paper_blip2();
        forall(
            "energy grows with f and b̂",
            200,
            |r| (r.range(1.0, 16.0), r.range(1e8, 2e9), r.range(1e8, 1e10)),
            |&(b, f, ft)| {
                let e = total_energy(&p, b, f, ft);
                if total_energy(&p, b + 1.0, f, ft) <= e {
                    return Err("not increasing in b̂".into());
                }
                if total_energy(&p, b, f * 1.1, ft) <= e {
                    return Err("not increasing in f".into());
                }
                if total_energy(&p, b, f, ft * 1.1) <= e {
                    return Err("not increasing in f̃".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn delay_form_equals_frequency_form() {
        // e(b̂, f) computed directly vs via the t1 parametrization
        let p = Platform::paper_blip2();
        forall(
            "energy(delay(f)) == energy(f)",
            100,
            |r| (r.range(1.0, 16.0), r.range(1e8, 2e9)),
            |&(b, f)| {
                let t1 = delay::agent_delay(&p, b, f);
                let direct = agent_energy(&p, b, f);
                let via_delay = agent_energy_of_delay(&p, b, t1);
                if (direct - via_delay).abs() / direct < 1e-9 {
                    Ok(())
                } else {
                    Err(format!("{direct} vs {via_delay}"))
                }
            },
        );
    }

    #[test]
    fn delay_energy_tradeoff_exists() {
        // raising f cuts delay but costs energy: the core coupling the
        // joint design exploits (Remark 4.1)
        let p = Platform::paper_blip2();
        let (b, f1, f2) = (8.0, 1.0e9, 2.0e9);
        assert!(delay::agent_delay(&p, b, f2) < delay::agent_delay(&p, b, f1));
        assert!(agent_energy(&p, b, f2) > agent_energy(&p, b, f1));
    }
}
