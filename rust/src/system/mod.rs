//! The paper's system model (§II): platform profiles, computation delay
//! (eq. 4–5, 8), energy (eq. 6–7, 9), DVFS governors, the (substrate)
//! wireless link carrying embeddings between agent and server, and the
//! shared edge-server queue the fleet contends on.

pub mod channel;
pub mod delay;
pub mod dvfs;
pub mod energy;
pub mod platform;
pub mod queue;

pub use platform::{DeviceProfile, DeviceSpec, Platform, ServerSpec};
pub use queue::{EdgeQueue, QueueDiscipline, QueueModel};
