//! Hardware profiles: the paper's simulation setup (§VI-C) and the
//! Jetson-AGX-Orin / Xeon+RTX3090 testbed (§VI, Table I), plus
//! measured-FLOPs presets for the models this repo actually ships.

use crate::util::cli::ParseError;

/// Agent-side processor (paper notation: f, c, η, ψ, b).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DeviceSpec {
    /// max clock frequency f^max [Hz]
    pub f_max: f64,
    /// FLOPs per cycle c
    pub flops_per_cycle: f64,
    /// power usage effectiveness η
    pub pue: f64,
    /// chip power coefficient ψ [W/(cycle/s)^3]
    pub psi: f64,
}

/// A named silicon tier for heterogeneous fleets: the device half of a
/// [`Platform`] plus the tier's nominal uplink quality. The fleet layer
/// ([`crate::opt::fleet`]) substitutes a profile's [`DeviceSpec`] into the
/// shared base platform per agent, so the paper's per-device constants
/// (f^max, the compute efficiency κ ≡ `flops_per_cycle`, and the cubic
/// power curve ηψf³) become per-agent quantities — the Sec. V joint
/// design's "per-device statistics".
///
/// Three presets span the embodied-silicon range the testbed literature
/// reports (Jetson AGX Orin vs. Xavier NX vs. phone-class SoCs — roughly
/// the device ladder of "The Larger the Merrier?", arXiv:2505.09214):
///
/// | tier     | f^max   | κ (FLOPs/cyc) | η    | ψ       | link gain |
/// |----------|---------|---------------|------|---------|-----------|
/// | `orin`   | 2.0 GHz | 32            | 1.00 | 2e-29   | 1.0       |
/// | `xavier` | 1.4 GHz | 16            | 1.10 | 3e-29   | 0.8       |
/// | `phone`  | 1.0 GHz | 8             | 1.20 | 5e-29   | 0.5       |
///
/// `orin` is **exactly** the paper's §VI-C device (the one every fleet
/// shared before heterogeneity existed), so a uniform-`orin` fleet
/// reproduces the homogeneous results bit for bit — the regression the
/// tier tests pin.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DeviceProfile {
    /// tier name (CLI `--tiers` token)
    pub tier: &'static str,
    /// the tier's silicon constants (frequency range [0, f^max], κ, power curve)
    pub spec: DeviceSpec,
    /// nominal uplink channel gain g ∈ (0, 1] of this tier's radio: the
    /// agent's effective share of the shared medium's goodput is α·g·R
    pub link_gain: f64,
}

impl DeviceProfile {
    /// Jetson-AGX-Orin class: the paper's §VI-C simulation device.
    pub fn orin() -> DeviceProfile {
        DeviceProfile {
            tier: "orin",
            spec: DeviceSpec { f_max: 2.0e9, flops_per_cycle: 32.0, pue: 1.0, psi: 2.0e-29 },
            link_gain: 1.0,
        }
    }

    /// Jetson-Xavier-NX class: lower clock ceiling, half the per-cycle
    /// throughput, a slightly worse power curve, and a weaker radio.
    pub fn xavier() -> DeviceProfile {
        DeviceProfile {
            tier: "xavier",
            spec: DeviceSpec { f_max: 1.4e9, flops_per_cycle: 16.0, pue: 1.1, psi: 3.0e-29 },
            link_gain: 0.8,
        }
    }

    /// Phone-class SoC (sustained, not burst, clocks): the weak end of
    /// the embodied fleet — a quarter of Orin's per-cycle throughput,
    /// the costliest power curve, and half the radio gain.
    pub fn phone() -> DeviceProfile {
        DeviceProfile {
            tier: "phone",
            spec: DeviceSpec { f_max: 1.0e9, flops_per_cycle: 8.0, pue: 1.2, psi: 5.0e-29 },
            link_gain: 0.5,
        }
    }

    /// Peak compute throughput f^max · κ [FLOP/s] — the tier's raw
    /// capability axis (strictly ordered down the ladder).
    pub fn peak_flops(&self) -> f64 {
        self.spec.f_max * self.spec.flops_per_cycle
    }

    /// Capability relative to the Orin reference tier, clamped to (0, 1]:
    /// 1.0 for Orin (and anything faster), 0.35 for Xavier, 0.125 for
    /// phone-class. This is the factor tier-aware admission pricing
    /// ([`crate::opt::fleet::AdmissionPricing::Tiered`]) scales the
    /// rejection penalty by — turning a weak device away forfeits
    /// proportionally less fleet capability than turning an Orin away.
    pub fn capability(&self) -> f64 {
        (self.peak_flops() / DeviceProfile::orin().peak_flops()).min(1.0)
    }

    /// CLI-facing parser; the error names the token and valid choices.
    pub fn parse(s: &str) -> Result<DeviceProfile, ParseError> {
        match s {
            "orin" => Ok(DeviceProfile::orin()),
            "xavier" => Ok(DeviceProfile::xavier()),
            "phone" => Ok(DeviceProfile::phone()),
            _ => Err(ParseError::new("silicon tier", s, &["orin", "xavier", "phone"])),
        }
    }

    /// Parse a CLI tier mix like `"orin,xavier,phone"`. The error
    /// carries the first offending tier token (an empty list reports the
    /// whole input as the offending token).
    pub fn parse_mix(s: &str) -> Result<Vec<DeviceProfile>, ParseError> {
        let tiers: Vec<DeviceProfile> = s
            .split(',')
            .map(str::trim)
            .map(DeviceProfile::parse)
            .collect::<Result<_, _>>()?;
        if tiers.is_empty() {
            return Err(ParseError::new("silicon tier mix", s, &["orin", "xavier", "phone"]));
        }
        Ok(tiers)
    }
}

/// Server-side processor (paper notation: f̃, c̃, η̃, ψ̃).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ServerSpec {
    pub f_max: f64,
    pub flops_per_cycle: f64,
    pub pue: f64,
    pub psi: f64,
}

/// A full co-inference platform: device + server + workload constants.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Platform {
    pub device: DeviceSpec,
    pub server: ServerSpec,
    /// full-precision on-agent workload N_FLOP
    pub n_flop_agent: f64,
    /// on-server workload Ñ_FLOP
    pub n_flop_server: f64,
    /// original parameter bit-width b (quantization scales work by b̂/b)
    pub full_bits: f64,
    /// achievable bit-width set B = {1..B_max}
    pub b_max: u32,
}

impl Platform {
    /// The paper's §VI-C simulation setup: f^max = 2 GHz, f̃^max = 10 GHz,
    /// c = 32, c̃ = 128, η = 1, η̃ = 2, ψ = 2e-29, ψ̃ = 1e-28, with the
    /// BLIP-2-2.7b first-token workload (533.66 GFLOPs) split 30/70 across
    /// the agent encoder and server decoder.
    pub fn paper_blip2() -> Platform {
        Platform {
            device: DeviceSpec {
                f_max: 2.0e9,
                flops_per_cycle: 32.0,
                pue: 1.0,
                psi: 2.0e-29,
            },
            server: ServerSpec {
                f_max: 10.0e9,
                flops_per_cycle: 128.0,
                pue: 2.0,
                psi: 1.0e-28,
            },
            n_flop_agent: 0.30 * 533.66e9,
            n_flop_server: 0.70 * 533.66e9,
            full_bits: 32.0,
            b_max: 16,
        }
    }

    /// Multi-tenant fleet edge server (the [`crate::opt::fleet`]
    /// scenario): the §VI-C agent silicon unchanged, but the shared edge
    /// box is a serving-class machine an order of magnitude more
    /// power-efficient (ψ̃ = 1e-29) than the paper's single-pair server.
    /// That moves the binding server resource from energy to the
    /// frequency budget f̃^max — the quantity the fleet allocator
    /// partitions across agents — which is the regime where N agents
    /// contending for one box is interesting at all.
    pub fn fleet_edge() -> Platform {
        let mut p = Platform::paper_blip2();
        p.server.psi = 1.0e-29;
        p
    }

    /// GIT-base on VaTeX: 212.27 GFLOPs first-token workload, same silicon.
    pub fn paper_git() -> Platform {
        Platform {
            n_flop_agent: 0.30 * 212.27e9,
            n_flop_server: 0.70 * 212.27e9,
            ..Platform::paper_blip2()
        }
    }

    /// Testbed preset (Table I): Jetson AGX Orin 64GB device (coarse DVFS
    /// profiles live in [`crate::system::dvfs`]) + dual Xeon 6246R/RTX3090
    /// server. Workloads are per the shipped models unless overridden.
    pub fn testbed(n_flop_agent: f64, n_flop_server: f64) -> Platform {
        Platform {
            device: DeviceSpec {
                f_max: 2.2e9,
                flops_per_cycle: 16.0,
                pue: 1.05,
                psi: 6.0e-29,
            },
            server: ServerSpec {
                f_max: 4.1e9,
                flops_per_cycle: 256.0,
                pue: 1.8,
                psi: 8.0e-29,
            },
            n_flop_agent,
            n_flop_server,
            full_bits: 32.0,
            b_max: 16,
        }
    }

    /// Scale the workloads (e.g. to the repo's measured model FLOPs) while
    /// keeping the silicon profile.
    pub fn with_workload(mut self, n_agent: f64, n_server: f64) -> Platform {
        self.n_flop_agent = n_agent;
        self.n_flop_server = n_server;
        self
    }

    /// Agent cycles at bit-width b̂: C1(b̂) = b̂ N / (b c) — the workload
    /// scaling assumption of §II-D.
    pub fn agent_cycles(&self, b_hat: f64) -> f64 {
        b_hat * self.n_flop_agent / (self.full_bits * self.device.flops_per_cycle)
    }

    /// Server cycles (bit-width independent; the server runs full
    /// precision): C2 = Ñ / c̃.
    pub fn server_cycles(&self) -> f64 {
        self.n_flop_server / self.server.flops_per_cycle
    }

    /// Hard floor on end-to-end delay at bit-width b̂ (both stages at
    /// their max frequency).
    pub fn min_delay(&self, b_hat: f64) -> f64 {
        self.agent_cycles(b_hat) / self.device.f_max
            + self.server_cycles() / self.server.f_max
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_preset_values() {
        let p = Platform::paper_blip2();
        assert_eq!(p.device.f_max, 2.0e9);
        assert_eq!(p.server.flops_per_cycle, 128.0);
        assert!((p.n_flop_agent + p.n_flop_server - 533.66e9).abs() < 1.0);
    }

    #[test]
    fn cycles_scale_linearly_with_bits() {
        let p = Platform::paper_blip2();
        let c8 = p.agent_cycles(8.0);
        let c16 = p.agent_cycles(16.0);
        assert!((c16 / c8 - 2.0).abs() < 1e-12);
    }

    #[test]
    fn min_delay_in_plausible_range() {
        // paper evaluates T0 in the ~2.5-4s band (Fig. 5): full precision
        // must be near/above it, low bits well below
        let p = Platform::paper_blip2();
        assert!(p.min_delay(32.0) > 2.0, "{}", p.min_delay(32.0));
        assert!(p.min_delay(2.0) < 1.0, "{}", p.min_delay(2.0));
    }

    #[test]
    fn orin_tier_is_exactly_the_paper_device() {
        // uniform-orin fleets must reproduce the homogeneous results bit
        // for bit, which requires the tier constants to *be* the §VI-C
        // device constants
        assert_eq!(DeviceProfile::orin().spec, Platform::paper_blip2().device);
        assert_eq!(DeviceProfile::orin().spec, Platform::fleet_edge().device);
        assert_eq!(DeviceProfile::orin().link_gain, 1.0);
    }

    #[test]
    fn tiers_are_strictly_ordered_in_capability() {
        // throughput f^max·κ strictly decreasing, power curve ψ and PUE
        // strictly increasing, radio gain strictly decreasing — a real
        // silicon ladder, not three relabelings of one device
        let ladder = [DeviceProfile::orin(), DeviceProfile::xavier(), DeviceProfile::phone()];
        for w in ladder.windows(2) {
            let (a, b) = (&w[0], &w[1]);
            assert!(a.spec.f_max * a.spec.flops_per_cycle > b.spec.f_max * b.spec.flops_per_cycle);
            assert!(a.spec.psi < b.spec.psi);
            assert!(a.spec.pue < b.spec.pue);
            assert!(a.link_gain > b.link_gain);
            assert!(b.link_gain > 0.0 && b.link_gain <= 1.0);
        }
    }

    #[test]
    fn capability_is_orin_normalized_and_ladder_ordered() {
        assert_eq!(DeviceProfile::orin().capability(), 1.0);
        let x = DeviceProfile::xavier().capability();
        let p = DeviceProfile::phone().capability();
        assert!((x - 0.35).abs() < 1e-12, "{x}");
        assert!((p - 0.125).abs() < 1e-12, "{p}");
        assert!(p < x && x < 1.0);
        // a hypothetical faster-than-orin tier clamps to 1 (the penalty
        // scale never exceeds the uniform one)
        let mut hot = DeviceProfile::orin();
        hot.spec.f_max *= 4.0;
        assert_eq!(hot.capability(), 1.0);
    }

    #[test]
    fn tier_parse_roundtrip_and_mix() {
        for p in [DeviceProfile::orin(), DeviceProfile::xavier(), DeviceProfile::phone()] {
            assert_eq!(DeviceProfile::parse(p.tier), Ok(p));
        }
        let err = DeviceProfile::parse("tpu").unwrap_err();
        assert_eq!(err.token, "tpu");
        assert_eq!(err.choices, ["orin", "xavier", "phone"]);
        let mix = DeviceProfile::parse_mix("orin, xavier,phone").unwrap();
        assert_eq!(
            mix.iter().map(|p| p.tier).collect::<Vec<_>>(),
            vec!["orin", "xavier", "phone"]
        );
        // the error names the offending token, not the whole list
        assert_eq!(DeviceProfile::parse_mix("orin,nope").unwrap_err().token, "nope");
        assert!(DeviceProfile::parse_mix("").is_err());
    }

    #[test]
    fn with_workload_overrides() {
        let p = Platform::paper_blip2().with_workload(1e9, 2e9);
        assert_eq!(p.n_flop_agent, 1e9);
        assert_eq!(p.n_flop_server, 2e9);
    }
}
