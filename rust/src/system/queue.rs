//! Shared edge-server queue: the dispatch stage between the per-agent
//! batchers and the server's frequency shares.
//!
//! PR 1's fleet model partitions the server's frequency into shares μ_i
//! and lets every agent's server stage run concurrently on its slice —
//! the optimistic, fluid end of the sharing spectrum. A real edge box
//! serializes admission (one DMA/KV-cache load, one dispatch path), so a
//! burst from one agent head-of-line blocks the others. This module
//! captures that interference twice, at matching fidelity levels:
//!
//! * [`QueueModel`] — the **analytic** feedback term the fleet allocator
//!   budgets against: a non-preemptive M/G/1 mean waiting time with
//!   deterministic per-agent service times, under FIFO or weighted-
//!   priority discipline. Agent i's service time is its slice-capacity
//!   drain time C̃/(μ_i f̃^max), so the wait is strictly decreasing in
//!   μ_i and the water-filling exchange in [`crate::opt::fleet`] stays
//!   exact coordinate descent. Rival agents enter through a mean-field
//!   estimate at the uniform split (their true shares are not visible to
//!   a separable per-agent cost), which keeps the term conservative and
//!   share-vector independent.
//! * [`EdgeQueue`] — the **event-level** queue the fleet serving loop
//!   ([`crate::fleet::sim`]) pushes actual jobs through: jobs from all
//!   agents serialize on one server, the discipline picks who goes next,
//!   and the measured per-request queue wait lands in telemetry.
//!
//! An overloaded queue (utilization ≥ 1) yields an **infinite** analytic
//! wait; [`crate::opt::fleet::FleetProblem::agent_problem`] turns that
//! into a clean rejection instead of letting ±inf/NaN poison the
//! exchange.

use crate::obs::metrics as obs_metrics;
use crate::util::cli::ParseError;

/// Service order at the shared edge queue.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum QueueDiscipline {
    /// first ready, first dispatched
    Fifo,
    /// non-preemptive priority by fleet weight (ties FIFO)
    WeightedPriority,
}

impl QueueDiscipline {
    pub fn name(self) -> &'static str {
        match self {
            QueueDiscipline::Fifo => "fifo",
            QueueDiscipline::WeightedPriority => "priority",
        }
    }

    /// CLI-facing parser; the error names the token and valid choices.
    pub fn parse(s: &str) -> Result<QueueDiscipline, ParseError> {
        match s {
            "fifo" => Ok(QueueDiscipline::Fifo),
            "priority" | "weighted-priority" => Ok(QueueDiscipline::WeightedPriority),
            _ => Err(ParseError::new("queue discipline", s, &["fifo", "priority"])),
        }
    }
}

/// Analytic queueing model: Poisson request arrivals per agent, one
/// serialized server, deterministic service times.
#[derive(Debug, Clone)]
pub struct QueueModel {
    pub discipline: QueueDiscipline,
    /// per-agent request arrival rate [req/s]
    pub arrival_rps: Vec<f64>,
}

impl QueueModel {
    pub fn new(discipline: QueueDiscipline, arrival_rps: Vec<f64>) -> QueueModel {
        assert!(!arrival_rps.is_empty(), "at least one agent");
        assert!(
            arrival_rps.iter().all(|&r| r.is_finite() && r >= 0.0),
            "arrival rates must be finite and non-negative: {arrival_rps:?}"
        );
        QueueModel { discipline, arrival_rps }
    }

    /// Every agent offering the same load.
    pub fn uniform(discipline: QueueDiscipline, n: usize, rps: f64) -> QueueModel {
        QueueModel::new(discipline, vec![rps; n])
    }

    /// Total offered utilization at a common reference service time
    /// (diagnostics; ≥ 1 means no discipline can keep up).
    pub fn utilization(&self, ref_service_s: f64) -> f64 {
        self.arrival_rps.iter().map(|r| r * ref_service_s).sum()
    }

    /// Mean queueing delay seen by `agent`, whose own jobs take
    /// `own_service_s`, with every rival estimated at `ref_service_s`
    /// (mean-field: the uniform-split drain time). `weight_of(j)` is
    /// agent j's priority weight — a lookup closure so the hot probe
    /// path (the water-filling exchange calls this per cost evaluation)
    /// never materializes a weights vector.
    ///
    /// Non-preemptive M/G/1 with deterministic service: the wait is the
    /// residual work R₀ = Σ_j r_j S_j²/2 inflated by the utilization of
    /// whoever may be dispatched first. Under FIFO that is the whole
    /// fleet (Pollaczek–Khinchine); under weighted priority, strictly
    /// heavier agents plus the agent's own class. Returns `INFINITY`
    /// when the relevant utilization reaches 1 (overload) or any input
    /// is non-finite — callers must treat that as "unservable here".
    pub fn expected_wait_s(
        &self,
        agent: usize,
        own_service_s: f64,
        ref_service_s: f64,
        weight_of: impl Fn(usize) -> f64,
    ) -> f64 {
        if !(own_service_s.is_finite() && own_service_s >= 0.0)
            || !(ref_service_s.is_finite() && ref_service_s >= 0.0)
        {
            return f64::INFINITY;
        }
        self.accumulate_wait(
            agent,
            |j| if j == agent { own_service_s } else { ref_service_s },
            |j| self.arrival_rps[j],
            weight_of,
        )
    }

    /// The one non-preemptive M/G/1 accumulation both estimators share:
    /// the wait of a virtual class-`i` arrival given per-agent service
    /// times and offered loads. Zero-load flows are invisible; an
    /// offered flow whose service never completes (non-finite) makes the
    /// wait infinite, as does overload of the dispatched-first
    /// utilization.
    ///
    /// Non-preemptive M/G/1 with deterministic service: the wait is the
    /// residual work R₀ = Σ_j load_j S_j²/2 inflated by the utilization
    /// of whoever may be dispatched first. Under FIFO that is the whole
    /// fleet (Pollaczek–Khinchine); under weighted priority, strictly
    /// heavier agents plus the agent's own class (strictly lighter
    /// agents only contribute residual work).
    fn accumulate_wait(
        &self,
        i: usize,
        service_of: impl Fn(usize) -> f64,
        load_of: impl Fn(usize) -> f64,
        weight_of: impl Fn(usize) -> f64,
    ) -> f64 {
        let w_own = weight_of(i);
        let mut residual = 0.0; // R0: mean residual work found on arrival
        let mut rho_ahead = 0.0; // strictly-higher-priority utilization
        let mut rho_class = 0.0; // own class (and self) utilization
        for j in 0..self.arrival_rps.len() {
            let load = load_of(j);
            if !(load > 0.0) {
                continue;
            }
            let s = service_of(j);
            if !s.is_finite() {
                return f64::INFINITY;
            }
            residual += load * s * s / 2.0;
            let rho = load * s;
            match self.discipline {
                QueueDiscipline::Fifo => rho_class += rho,
                QueueDiscipline::WeightedPriority => {
                    let w = weight_of(j);
                    if w > w_own {
                        rho_ahead += rho;
                    } else if j == i || w == w_own {
                        rho_class += rho;
                    }
                }
            }
        }
        let d1 = 1.0 - rho_ahead;
        let d2 = 1.0 - rho_ahead - rho_class;
        if d1 <= 0.0 || d2 <= 0.0 {
            return f64::INFINITY;
        }
        residual / (d1 * d2)
    }

    /// Per-agent waits with **actual** per-agent service times — the
    /// sharpened estimate the fixed-point interference pass in
    /// [`crate::opt::fleet`] evaluates, replacing the mean-field
    /// `ref_service_s` of [`Self::expected_wait_s`] with each rival's
    /// own slice-capacity drain time. `activity[j]` scales rival j's
    /// offered load (0 drops the flow entirely — a rejected agent's
    /// traffic is turned away at admission, so rivals never see it).
    ///
    /// Per agent: infinite own service ⇒ infinite wait; an *active*
    /// rival with infinite service ⇒ infinite wait (its backlog never
    /// drains); overload of the relevant utilization ⇒ infinite wait.
    /// Monotone increasing in every active rival's service time, which
    /// is what brackets the result between the mean-field estimates at
    /// the fastest and slowest active service (property-tested below).
    pub fn waits_given(
        &self,
        service_s: &[f64],
        activity: &[f64],
        weight_of: impl Fn(usize) -> f64,
    ) -> Vec<f64> {
        let n = self.arrival_rps.len();
        assert_eq!(service_s.len(), n);
        assert_eq!(activity.len(), n);
        (0..n).map(|i| self.wait_given_one(i, service_s, activity, &weight_of)).collect()
    }

    /// Row `i` of [`Self::waits_given`], exposed on its own so the
    /// classed fleet solver can compute one row per equivalence class
    /// and broadcast it (the row depends on the observer only through
    /// its priority weight and the finiteness guard on its own service).
    pub fn wait_given_one(
        &self,
        i: usize,
        service_s: &[f64],
        activity: &[f64],
        weight_of: impl Fn(usize) -> f64,
    ) -> f64 {
        let s_i = service_s[i];
        if !(s_i.is_finite() && s_i >= 0.0) {
            return f64::INFINITY;
        }
        self.accumulate_wait(
            i,
            |j| service_s[j],
            |j| self.arrival_rps[j] * activity[j],
            &weight_of,
        )
    }
}

/// One job waiting at (or flowing through) the shared edge queue.
#[derive(Debug, Clone, Copy)]
pub struct QueuedJob {
    pub agent: usize,
    /// caller-side request handle carried through dispatch untouched (the
    /// event-level churn engine keys its per-request metadata on it;
    /// plain [`EdgeQueue::push`] leaves it 0)
    pub tag: u64,
    /// simulated time the job became ready for the server stage
    pub ready_s: f64,
    /// server-stage service time at the agent's planned frequency
    pub service_s: f64,
    /// fleet weight (the priority key)
    pub weight: f64,
    /// arrival sequence number (FIFO tie-break)
    seq: u64,
}

/// Event-level shared queue: jobs from every agent serialize on one
/// server; `pop` dispatches them under the configured discipline.
#[derive(Debug, Clone)]
pub struct EdgeQueue {
    pub discipline: QueueDiscipline,
    waiting: Vec<QueuedJob>,
    free_at: f64,
    seq: u64,
    /// jobs dispatched so far
    pub served: u64,
    /// total service time dispatched (work conservation check)
    pub busy_s: f64,
}

impl EdgeQueue {
    pub fn new(discipline: QueueDiscipline) -> EdgeQueue {
        EdgeQueue { discipline, waiting: Vec::new(), free_at: 0.0, seq: 0, served: 0, busy_s: 0.0 }
    }

    pub fn push(&mut self, agent: usize, ready_s: f64, service_s: f64, weight: f64) {
        self.push_tagged(agent, 0, ready_s, service_s, weight);
    }

    /// [`Self::push`] with a caller-side request handle that rides along
    /// to dispatch (see [`QueuedJob::tag`]). Validates the weight too —
    /// a NaN priority key used to slip in here and only blow up later
    /// inside `pop`'s comparator (regression-tested below).
    pub fn push_tagged(
        &mut self,
        agent: usize,
        tag: u64,
        ready_s: f64,
        service_s: f64,
        weight: f64,
    ) {
        assert!(ready_s.is_finite() && service_s.is_finite() && service_s >= 0.0);
        assert!(weight.is_finite(), "priority weight must be finite");
        self.waiting.push(QueuedJob { agent, tag, ready_s, service_s, weight, seq: self.seq });
        self.seq += 1;
        obs_metrics::counter_add("queue.push", 1);
        obs_metrics::observe("queue.depth", self.waiting.len() as f64);
    }

    pub fn len(&self) -> usize {
        self.waiting.len()
    }

    /// Waiting jobs belonging to one agent — the per-agent slice of
    /// [`Self::len`]. The event replay's closed-loop invariant (at most
    /// one outstanding request per client) is checked against this.
    pub fn backlog_of(&self, agent: usize) -> usize {
        self.waiting.iter().filter(|j| j.agent == agent).count()
    }

    pub fn is_empty(&self) -> bool {
        self.waiting.is_empty()
    }

    /// When the server next becomes idle.
    pub fn free_at(&self) -> f64 {
        self.free_at
    }

    /// Outstanding work at `now`, in seconds of service: the residual of
    /// the job in flight plus every waiting job's priced service time —
    /// the expected drain time were arrivals to stop. The serving
    /// daemon's hysteresis gate reads this as its urgency signal.
    pub fn backlog_s(&self, now: f64) -> f64 {
        (self.free_at - now).max(0.0) + self.waiting.iter().map(|j| j.service_s).sum::<f64>()
    }

    /// Dispatch the next job: among jobs ready by the instant the server
    /// can start (its free time, or the earliest readiness if it would
    /// idle), FIFO picks the earliest-ready and weighted priority the
    /// heaviest. Returns the job with its start and finish times.
    pub fn pop(&mut self) -> Option<(QueuedJob, f64, f64)> {
        self.pop_due(f64::INFINITY)
    }

    /// [`Self::pop`] bounded by a slot boundary: dispatch the next job
    /// only if its service would **start strictly before** `until`;
    /// otherwise leave the queue untouched and return `None`.
    ///
    /// This is the fix for the slot-boundary clock drift the event-level
    /// churn replay would otherwise suffer: an unbounded `pop` at a churn
    /// event commits jobs that really start *after* the event at their
    /// stale pre-event service times (and before jobs that only become
    /// visible in the next slot). Gating on the start floor makes the
    /// dispatch sequence invariant under slot refinement — inserting
    /// no-op boundaries (ticks) anywhere cannot change any job's start or
    /// finish time (property-tested in [`crate::fleet::events`]) — and
    /// lets a re-allocation [`Self::reprice`] everything still waiting.
    ///
    /// The gate is exact, not conservative: `start_floor` is the earliest
    /// instant *any* waiting job can start, and the selected job always
    /// starts at it (selection only ever returns a job that is ready by
    /// the floor), so `start_floor >= until` defers nothing dispatchable.
    pub fn pop_due(&mut self, until: f64) -> Option<(QueuedJob, f64, f64)> {
        if self.waiting.is_empty() {
            return None;
        }
        let earliest = self
            .waiting
            .iter()
            .map(|j| j.ready_s)
            .fold(f64::INFINITY, f64::min);
        let start_floor = self.free_at.max(earliest);
        if start_floor >= until {
            return None;
        }
        let fifo_key = |j: &QueuedJob| (j.ready_s, j.seq);
        let mut best = 0;
        for k in 1..self.waiting.len() {
            let (b, c) = (&self.waiting[best], &self.waiting[k]);
            let b_ready = b.ready_s <= start_floor;
            let c_ready = c.ready_s <= start_floor;
            let better = match (b_ready, c_ready) {
                (true, false) => false,
                (false, true) => true,
                // both ready: the discipline decides; both still arriving:
                // same keys stand in (harmless — a ready job always wins
                // the scan, and at least one is ready at the start floor)
                _ => match self.discipline {
                    QueueDiscipline::Fifo => fifo_key(c) < fifo_key(b),
                    QueueDiscipline::WeightedPriority => c
                        .weight
                        .partial_cmp(&b.weight)
                        .expect("weights are finite")
                        .then_with(|| {
                            // heavier first; ties dispatch FIFO
                            if fifo_key(c) < fifo_key(b) {
                                std::cmp::Ordering::Greater
                            } else {
                                std::cmp::Ordering::Less
                            }
                        })
                        .is_gt(),
                },
            };
            if better {
                best = k;
            }
        }
        let job = self.waiting.swap_remove(best);
        let start = self.free_at.max(job.ready_s);
        let finish = start + job.service_s;
        self.free_at = finish;
        self.served += 1;
        self.busy_s += job.service_s;
        obs_metrics::counter_add("queue.pop", 1);
        obs_metrics::observe("queue.wait_s", start - job.ready_s);
        obs_metrics::observe("queue.depth", self.waiting.len() as f64);
        Some((job, start, finish))
    }

    /// Remove every **waiting** job of `agent` and hand them back — the
    /// departure path of the event-level churn replay: when an agent
    /// leaves mid-service, its in-flight job (already popped) drains on
    /// the server, but its queued backlog must be explicitly dropped and
    /// accounted, never silently stranded (conservation of requests).
    pub fn drain_agent(&mut self, agent: usize) -> Vec<QueuedJob> {
        let mut removed = Vec::new();
        self.waiting.retain(|j| {
            if j.agent == agent {
                removed.push(*j);
                false
            } else {
                true
            }
        });
        obs_metrics::counter_add("queue.drain.calls", 1);
        obs_metrics::counter_add("queue.drain.jobs", removed.len() as u64);
        removed
    }

    /// Re-price every waiting job (a fleet re-allocation swapped the
    /// share vector without resetting the queue): `f` maps a job to its
    /// new `(service_s, weight)`. Ready times are untouched — the agent
    /// and uplink stages already ran at their old operating point; only
    /// the not-yet-started server stage follows the new shares. Combined
    /// with the slot-bounded [`Self::pop_due`], waiting jobs are always
    /// dispatched at the prices in force when their service starts.
    pub fn reprice(&mut self, mut f: impl FnMut(&QueuedJob) -> (f64, f64)) {
        obs_metrics::counter_add("queue.reprice.calls", 1);
        obs_metrics::counter_add("queue.reprice.jobs", self.waiting.len() as u64);
        for job in &mut self.waiting {
            let (service_s, weight) = f(job);
            assert!(service_s.is_finite() && service_s >= 0.0 && weight.is_finite());
            job.service_s = service_s;
            job.weight = weight;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn drain(q: &mut EdgeQueue) -> Vec<(usize, f64, f64)> {
        let mut out = Vec::new();
        while let Some((job, start, finish)) = q.pop() {
            out.push((job.agent, start, finish));
        }
        out
    }

    #[test]
    fn fifo_dispatches_in_ready_order() {
        let mut q = EdgeQueue::new(QueueDiscipline::Fifo);
        q.push(0, 0.3, 1.0, 1.0);
        q.push(1, 0.1, 1.0, 5.0);
        q.push(2, 0.2, 1.0, 9.0);
        let order: Vec<usize> = drain(&mut q).iter().map(|&(a, _, _)| a).collect();
        assert_eq!(order, vec![1, 2, 0], "weights must not matter under FIFO");
    }

    #[test]
    fn backlog_of_counts_only_the_agents_waiting_jobs() {
        let mut q = EdgeQueue::new(QueueDiscipline::Fifo);
        q.push(0, 0.0, 1.0, 1.0);
        q.push(1, 0.1, 1.0, 1.0);
        q.push(0, 0.2, 1.0, 1.0);
        assert_eq!(q.backlog_of(0), 2);
        assert_eq!(q.backlog_of(1), 1);
        assert_eq!(q.backlog_of(2), 0);
        assert_eq!(q.backlog_of(0) + q.backlog_of(1), q.len());
        q.pop(); // agent 0's first job starts: it is no longer waiting
        assert_eq!(q.backlog_of(0), 1);
        q.drain_agent(0);
        assert_eq!(q.backlog_of(0), 0);
    }

    #[test]
    fn priority_dispatches_heaviest_waiting_job() {
        let mut q = EdgeQueue::new(QueueDiscipline::WeightedPriority);
        q.push(0, 0.0, 1.0, 0.5);
        q.push(1, 0.0, 1.0, 2.0);
        q.push(2, 0.0, 1.0, 1.0);
        let order: Vec<usize> = drain(&mut q).iter().map(|&(a, _, _)| a).collect();
        assert_eq!(order, vec![1, 2, 0]);
    }

    #[test]
    fn priority_is_non_preemptive() {
        // the light job is alone at t=0 and starts; the heavy job arriving
        // at t=0.5 must wait for it to finish, not preempt
        let mut q = EdgeQueue::new(QueueDiscipline::WeightedPriority);
        q.push(0, 0.0, 2.0, 0.5);
        q.push(1, 0.5, 1.0, 9.0);
        let served = drain(&mut q);
        assert_eq!(served[0].0, 0);
        assert_eq!(served[1], (1, 2.0, 3.0));
    }

    #[test]
    fn head_of_line_blocking_delays_later_agents() {
        // a burst from agent 0 arrives first; agent 1's job, ready just
        // after, waits behind the whole burst under FIFO
        let mut q = EdgeQueue::new(QueueDiscipline::Fifo);
        for k in 0..4 {
            q.push(0, 0.01 * k as f64, 1.0, 1.0);
        }
        q.push(1, 0.05, 1.0, 1.0);
        let served = drain(&mut q);
        let (agent, start, _) = served[4];
        assert_eq!(agent, 1);
        assert!((start - 4.0).abs() < 1e-12, "start {start}");
    }

    #[test]
    fn server_idles_to_earliest_job_when_nothing_is_ready() {
        let mut q = EdgeQueue::new(QueueDiscipline::Fifo);
        q.push(0, 5.0, 1.0, 1.0);
        let (_, start, finish) = q.pop().unwrap();
        assert_eq!((start, finish), (5.0, 6.0));
        assert_eq!(q.free_at(), 6.0);
    }

    #[test]
    fn work_is_conserved() {
        let mut q = EdgeQueue::new(QueueDiscipline::WeightedPriority);
        for k in 0..10usize {
            q.push(k % 3, 0.1 * k as f64, 0.5, (k % 3) as f64);
        }
        drain(&mut q);
        assert_eq!(q.served, 10);
        assert!((q.busy_s - 5.0).abs() < 1e-12);
    }

    #[test]
    fn fifo_wait_matches_pollaczek_khinchine_shape() {
        // utilization below 1: finite wait, increasing in load
        let q1 = QueueModel::uniform(QueueDiscipline::Fifo, 4, 0.02);
        let q2 = QueueModel::uniform(QueueDiscipline::Fifo, 4, 0.08);
        let w = [1.0, 1.0, 1.0, 1.0];
        let (s_own, s_ref) = (1.0, 1.0);
        let w1 = q1.expected_wait_s(0, s_own, s_ref, |j| w[j]);
        let w2 = q2.expected_wait_s(0, s_own, s_ref, |j| w[j]);
        assert!(w1.is_finite() && w1 > 0.0);
        assert!(w2 > w1, "wait must grow with load: {w2} vs {w1}");
        // closed form: R0 / (1 - rho) with R0 = n r s^2 / 2
        let rho = 4.0 * 0.02;
        assert!((w1 - (4.0 * 0.02 * 0.5) / (1.0 - rho)).abs() < 1e-12);
    }

    #[test]
    fn overload_yields_infinite_wait() {
        let q = QueueModel::uniform(QueueDiscipline::Fifo, 2, 0.6);
        let w = [1.0, 1.0];
        assert!(q.expected_wait_s(0, 1.0, 1.0, |j| w[j]).is_infinite());
        assert!((q.utilization(1.0) - 1.2).abs() < 1e-12);
    }

    #[test]
    fn priority_shields_heavy_agents_from_light_load() {
        // heavy agent (w=2) vs two light ones (w=0.5): under priority the
        // heavy agent's wait ignores the light agents' utilization (they
        // still contribute residual work), so it must sit strictly below
        // its FIFO wait; the light agents pay at least FIFO
        let rates = vec![0.05, 0.05, 0.05];
        let weights = [2.0, 0.5, 0.5];
        let fifo = QueueModel::new(QueueDiscipline::Fifo, rates.clone());
        let prio = QueueModel::new(QueueDiscipline::WeightedPriority, rates);
        let heavy_fifo = fifo.expected_wait_s(0, 2.0, 2.0, |j| weights[j]);
        let heavy_prio = prio.expected_wait_s(0, 2.0, 2.0, |j| weights[j]);
        let light_fifo = fifo.expected_wait_s(1, 2.0, 2.0, |j| weights[j]);
        let light_prio = prio.expected_wait_s(1, 2.0, 2.0, |j| weights[j]);
        assert!(heavy_prio < heavy_fifo, "{heavy_prio} !< {heavy_fifo}");
        assert!(light_prio >= light_fifo, "{light_prio} < {light_fifo}");
    }

    #[test]
    fn wait_decreases_in_own_service_time() {
        // faster own service (a bigger server share) strictly reduces the
        // agent's analytic wait — the monotonicity the water-filling needs
        let q = QueueModel::uniform(QueueDiscipline::Fifo, 4, 0.03);
        let w = [1.0; 4];
        let mut prev = f64::INFINITY;
        for s_own in [4.0, 2.0, 1.0, 0.5, 0.25] {
            let wait = q.expected_wait_s(2, s_own, 1.0, |j| w[j]);
            assert!(wait < prev, "s_own {s_own}: {wait} !< {prev}");
            prev = wait;
        }
    }

    #[test]
    fn non_finite_service_rejected_cleanly() {
        let q = QueueModel::uniform(QueueDiscipline::Fifo, 2, 0.1);
        let w = [1.0, 1.0];
        assert!(q.expected_wait_s(0, f64::INFINITY, 1.0, |j| w[j]).is_infinite());
        assert!(q.expected_wait_s(0, f64::NAN, 1.0, |j| w[j]).is_infinite());
        assert!(q.expected_wait_s(0, 1.0, f64::NAN, |j| w[j]).is_infinite());
    }

    #[test]
    fn waits_given_reduces_to_mean_field_at_uniform_services() {
        // with every agent at the reference service time and full
        // activity, the actual-shares form IS the mean-field form
        use crate::util::prop::forall;
        forall(
            "waits_given == expected_wait_s at uniform services",
            150,
            |r| {
                let n = 1 + r.below(7);
                let rps = r.range(0.001, 0.4 / n as f64);
                let s = r.range(0.1, 2.0);
                let weights: Vec<f64> = (0..n).map(|_| r.range(0.5, 3.0)).collect();
                let fifo = r.f64() < 0.5;
                (n, rps, s, weights, fifo)
            },
            |(n, rps, s, weights, fifo)| {
                let d = if *fifo {
                    QueueDiscipline::Fifo
                } else {
                    QueueDiscipline::WeightedPriority
                };
                let q = QueueModel::uniform(d, *n, *rps);
                let waits = q.waits_given(&vec![*s; *n], &vec![1.0; *n], |j| weights[j]);
                for i in 0..*n {
                    let mf = q.expected_wait_s(i, *s, *s, |j| weights[j]);
                    let both_infinite = waits[i].is_infinite() && mf.is_infinite();
                    if (waits[i] - mf).abs() > 1e-12 && !both_infinite {
                        return Err(format!("agent {i}: {} vs mean-field {mf}", waits[i]));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_wait_strictly_decreasing_in_server_share() {
        // satellite property: an agent's expected wait is strictly
        // decreasing in its server share μ (service = drain / μ), under
        // both disciplines — the monotonicity the water-filling exchange
        // needs from the queue term
        use crate::util::prop::forall;
        forall(
            "expected wait strictly decreasing in server share",
            200,
            |r| {
                let n = 2 + r.below(6);
                let rps = r.range(0.005, 0.15 / n as f64);
                let drain = r.range(0.1, 1.5);
                let mu_lo = r.range(0.05, 0.5);
                let mu_hi = (mu_lo + r.range(0.05, 0.5)).min(1.0);
                let fifo = r.f64() < 0.5;
                (n, rps, drain, mu_lo, mu_hi, fifo)
            },
            |&(n, rps, drain, mu_lo, mu_hi, fifo)| {
                let d = if fifo {
                    QueueDiscipline::Fifo
                } else {
                    QueueDiscipline::WeightedPriority
                };
                let q = QueueModel::uniform(d, n, rps);
                let w = vec![1.0; n];
                let reference = drain * n as f64;
                let w_lo = q.expected_wait_s(0, drain / mu_lo, reference, |j| w[j]);
                let w_hi = q.expected_wait_s(0, drain / mu_hi, reference, |j| w[j]);
                if w_hi < w_lo || (w_hi.is_infinite() && w_lo.is_infinite()) {
                    Ok(())
                } else {
                    Err(format!("μ {mu_lo}->{mu_hi}: wait {w_lo} -> {w_hi} not decreasing"))
                }
            },
        );
    }

    #[test]
    fn prop_priority_no_worse_than_fifo_for_top_weight_agent() {
        // satellite property: the strictly-heaviest agent can only gain
        // from weighted priority — its priority wait divides by its own
        // class utilization alone, FIFO by the whole fleet's
        use crate::util::prop::forall;
        forall(
            "priority <= FIFO for the top-weight agent",
            200,
            |r| {
                let n = 2 + r.below(6);
                let rates: Vec<f64> = (0..n).map(|_| r.range(0.001, 0.3 / n as f64)).collect();
                let services: Vec<f64> = (0..n).map(|_| r.range(0.1, 2.0)).collect();
                let mut weights: Vec<f64> = (0..n).map(|_| r.range(0.2, 1.5)).collect();
                let top = r.below(n);
                weights[top] = 2.0; // unique strict maximum
                (rates, services, weights, top)
            },
            |(rates, services, weights, top)| {
                let fifo = QueueModel::new(QueueDiscipline::Fifo, rates.clone());
                let prio = QueueModel::new(QueueDiscipline::WeightedPriority, rates.clone());
                let act = vec![1.0; rates.len()];
                let wf = fifo.waits_given(services, &act, |j| weights[j])[*top];
                let wp = prio.waits_given(services, &act, |j| weights[j])[*top];
                if wp <= wf || wf.is_infinite() {
                    Ok(())
                } else {
                    Err(format!("priority {wp} > fifo {wf}"))
                }
            },
        );
    }

    #[test]
    fn prop_actual_service_waits_lie_in_mean_field_bracket() {
        // satellite property: with heterogeneous service times, the
        // actual-shares wait of every agent lies between the mean-field
        // estimates taken at the fastest and at the slowest service in
        // the fleet — waits_given is monotone in every rival's service,
        // so the actual mix can sharpen the mean-field family's envelope
        // but never exit it
        use crate::util::prop::forall;
        forall(
            "waits_given within [all-fastest, all-slowest] mean-field bracket",
            200,
            |r| {
                let n = 2 + r.below(6);
                let rates: Vec<f64> = (0..n).map(|_| r.range(0.001, 0.25 / n as f64)).collect();
                let services: Vec<f64> = (0..n).map(|_| r.range(0.05, 3.0)).collect();
                let weights: Vec<f64> = (0..n).map(|_| r.range(0.5, 3.0)).collect();
                let fifo = r.f64() < 0.5;
                (rates, services, weights, fifo)
            },
            |(rates, services, weights, fifo)| {
                let d = if *fifo {
                    QueueDiscipline::Fifo
                } else {
                    QueueDiscipline::WeightedPriority
                };
                let q = QueueModel::new(d, rates.clone());
                let n = rates.len();
                let act = vec![1.0; n];
                let actual = q.waits_given(services, &act, |j| weights[j]);
                let s_min = services.iter().cloned().fold(f64::INFINITY, f64::min);
                let s_max = services.iter().cloned().fold(0.0f64, f64::max);
                for i in 0..n {
                    let mut lo_vec = vec![s_min; n];
                    lo_vec[i] = services[i];
                    let mut hi_vec = vec![s_max; n];
                    hi_vec[i] = services[i];
                    let lo = q.waits_given(&lo_vec, &act, |j| weights[j])[i];
                    let hi = q.waits_given(&hi_vec, &act, |j| weights[j])[i];
                    if actual[i] < lo - 1e-12 {
                        return Err(format!("agent {i}: {} below bracket floor {lo}", actual[i]));
                    }
                    if actual[i] > hi + 1e-12 && hi.is_finite() {
                        return Err(format!("agent {i}: {} above bracket ceiling {hi}", actual[i]));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn waits_given_activity_drops_flows_and_infinite_service_propagates() {
        let q = QueueModel::uniform(QueueDiscipline::Fifo, 3, 0.1);
        let w = [1.0; 3];
        // dropping rival flows can only reduce the wait
        let all = q.waits_given(&[1.0, 1.0, 1.0], &[1.0, 1.0, 1.0], |j| w[j]);
        let one = q.waits_given(&[1.0, 1.0, 1.0], &[1.0, 0.0, 0.0], |j| w[j]);
        assert!(one[0] < all[0]);
        // an *active* rival that can never drain poisons everyone ...
        let poisoned = q.waits_given(&[1.0, f64::INFINITY, 1.0], &[1.0, 1.0, 1.0], |j| w[j]);
        assert!(poisoned.iter().all(|x| x.is_infinite()));
        // ... but an inactive one is invisible to rivals (infinite only
        // for itself)
        let dropped = q.waits_given(&[1.0, f64::INFINITY, 1.0], &[1.0, 0.0, 1.0], |j| w[j]);
        assert!(dropped[0].is_finite() && dropped[2].is_finite());
        assert!(dropped[1].is_infinite());
    }

    #[test]
    fn pop_due_defers_jobs_starting_at_or_after_the_boundary() {
        // job ready at 5: a slot ending at 5 must NOT dispatch it (its
        // start == the boundary, where a churn event may re-price it);
        // any boundary beyond 5 dispatches it at exactly the same times
        // the unbounded pop would
        let mut q = EdgeQueue::new(QueueDiscipline::Fifo);
        q.push(0, 5.0, 1.0, 1.0);
        assert!(q.pop_due(4.0).is_none());
        assert!(q.pop_due(5.0).is_none(), "start == boundary belongs to the next slot");
        assert_eq!(q.len(), 1, "deferral must not consume the job");
        let (_, start, finish) = q.pop_due(5.0 + 1e-9).unwrap();
        assert_eq!((start, finish), (5.0, 6.0));
        // busy server: the floor is free_at, not readiness
        q.push(1, 0.0, 1.0, 1.0);
        assert!(q.pop_due(6.0).is_none(), "server busy until 6");
        let (job, start, _) = q.pop_due(7.0).unwrap();
        assert_eq!((job.agent, start), (1, 6.0));
    }

    #[test]
    fn pop_due_is_invariant_under_slot_refinement() {
        // dispatching through arbitrary slot boundaries yields exactly
        // the unbounded dispatch sequence — the slot-boundary clock-drift
        // regression, at queue level
        let jobs: [(usize, f64, f64, f64); 6] = [
            (0, 0.3, 1.0, 1.0),
            (1, 0.1, 0.7, 5.0),
            (2, 0.2, 1.3, 9.0),
            (0, 2.0, 0.5, 1.0),
            (1, 2.1, 0.4, 5.0),
            (2, 6.5, 1.0, 9.0),
        ];
        for d in [QueueDiscipline::Fifo, QueueDiscipline::WeightedPriority] {
            let filled = || {
                let mut q = EdgeQueue::new(d);
                for &(a, r, s, w) in &jobs {
                    q.push(a, r, s, w);
                }
                q
            };
            let mut plain = filled();
            let mut reference = Vec::new();
            while let Some((job, start, finish)) = plain.pop() {
                reference.push((job.agent, job.seq, start, finish));
            }
            let mut sliced = filled();
            let mut got = Vec::new();
            for boundary in [0.5, 1.0, 2.05, 3.0, 6.0, 7.0, f64::INFINITY] {
                while let Some((job, start, finish)) = sliced.pop_due(boundary) {
                    got.push((job.agent, job.seq, start, finish));
                }
            }
            assert_eq!(got, reference, "{d:?}: slot boundaries changed the dispatch");
        }
    }

    #[test]
    fn drain_agent_conserves_requests() {
        // conservation regression: every pushed job is either dispatched
        // or handed back by drain_agent — nothing stranded, nothing
        // duplicated
        let mut q = EdgeQueue::new(QueueDiscipline::Fifo);
        for k in 0..9usize {
            q.push(k % 3, 0.2 * k as f64, 1.0, 1.0);
        }
        let mut dispatched = 0;
        while q.pop_due(1.5).is_some() {
            dispatched += 1;
        }
        let dropped = q.drain_agent(1);
        assert!(dropped.iter().all(|j| j.agent == 1));
        let mut rest = 0;
        while q.pop().is_some() {
            rest += 1;
        }
        assert_eq!(dispatched + dropped.len() + rest, 9, "requests not conserved");
        assert!(!dropped.is_empty(), "agent 1 should have had queued backlog");
        assert!(q.is_empty());
        // draining an absent agent is a no-op
        assert!(q.drain_agent(7).is_empty());
    }

    #[test]
    fn reprice_rewrites_waiting_jobs_only() {
        let mut q = EdgeQueue::new(QueueDiscipline::WeightedPriority);
        q.push_tagged(0, 11, 0.0, 2.0, 1.0);
        q.push_tagged(1, 22, 0.0, 2.0, 5.0);
        // first job enters service at its old price
        let (job, _, finish) = q.pop().unwrap();
        assert_eq!((job.agent, job.tag, finish), (1, 22, 2.0));
        // the waiting job is re-priced: shorter service, heavier weight
        q.reprice(|j| {
            assert_eq!((j.agent, j.tag), (0, 11));
            (0.5, 3.0)
        });
        let (job, start, finish) = q.pop().unwrap();
        assert_eq!((job.agent, job.tag), (0, 11));
        assert_eq!((start, finish), (2.0, 2.5));
    }

    #[test]
    #[should_panic(expected = "priority weight must be finite")]
    fn nan_weight_rejected_at_push() {
        // regression: a NaN priority key used to be accepted here and
        // only panic later inside pop's comparator
        EdgeQueue::new(QueueDiscipline::WeightedPriority).push(0, 0.0, 1.0, f64::NAN);
    }

    #[test]
    fn queue_operations_record_ambient_metrics() {
        use crate::util::timer::Samples;
        let ((), m) = crate::obs::metrics::scoped(|| {
            let mut q = EdgeQueue::new(QueueDiscipline::Fifo);
            q.push(0, 0.0, 1.0, 1.0);
            q.push(1, 0.5, 1.0, 1.0);
            q.pop().unwrap();
            q.reprice(|j| (j.service_s, j.weight));
            assert_eq!(q.drain_agent(1).len(), 1);
        });
        assert_eq!(m.counter("queue.push"), 2);
        assert_eq!(m.counter("queue.pop"), 1);
        assert_eq!(m.counter("queue.reprice.calls"), 1);
        assert_eq!(m.counter("queue.reprice.jobs"), 1);
        assert_eq!(m.counter("queue.drain.calls"), 1);
        assert_eq!(m.counter("queue.drain.jobs"), 1);
        // depth observed on both pushes and the pop; wait on the pop only
        assert_eq!(m.histogram("queue.depth").map(Samples::len), Some(3));
        assert_eq!(m.histogram("queue.wait_s").map(Samples::len), Some(1));
    }

    #[test]
    fn discipline_parse_roundtrip() {
        for d in [QueueDiscipline::Fifo, QueueDiscipline::WeightedPriority] {
            assert_eq!(QueueDiscipline::parse(d.name()), Ok(d));
        }
        assert_eq!(
            QueueDiscipline::parse("weighted-priority"),
            Ok(QueueDiscipline::WeightedPriority)
        );
        let err = QueueDiscipline::parse("lifo").unwrap_err();
        assert_eq!(err.token, "lifo");
        assert_eq!(err.choices, ["fifo", "priority"]);
    }
}
