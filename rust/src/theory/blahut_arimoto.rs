//! Blahut–Arimoto estimation of the true D(R) (paper §VI-B, Fig. 4).
//!
//! The continuous Exp(λ) source is discretized on a fine grid; for each
//! Lagrange multiplier s < 0 the classical BA iteration converges to a
//! point (R(s), D(s)) on the rate–distortion curve; sweeping s traces the
//! curve that the analytical bounds of §IV sandwich.

/// One converged BA point.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RdPoint {
    pub rate_bits: f64,
    pub distortion: f64,
}

pub struct BlahutArimoto {
    /// source grid values
    x: Vec<f64>,
    /// source probabilities
    p: Vec<f64>,
    /// reproduction grid values
    y: Vec<f64>,
}

impl BlahutArimoto {
    /// Discretize Exp(λ): support truncated at `k_sigma` means, `n` bins.
    /// Probability mass per bin via CDF differences (exact), reproduction
    /// alphabet = the same grid.
    pub fn exponential(lambda: f64, n: usize, k_sigma: f64) -> BlahutArimoto {
        assert!(lambda > 0.0 && n >= 8);
        let max = k_sigma / lambda;
        let width = max / n as f64;
        let cdf = |t: f64| 1.0 - (-lambda * t).exp();
        let mut x = Vec::with_capacity(n);
        let mut p = Vec::with_capacity(n);
        for i in 0..n {
            let lo = i as f64 * width;
            let hi = lo + width;
            x.push(lo + 0.5 * width);
            p.push(cdf(hi) - cdf(lo));
        }
        // fold the tail mass into the last bin so Σp = 1 exactly
        let tail = 1.0 - cdf(max);
        *p.last_mut().unwrap() += tail;
        BlahutArimoto { y: x.clone(), x, p }
    }

    fn distortion(&self, i: usize, j: usize) -> f64 {
        (self.x[i] - self.y[j]).abs()
    }

    /// Run BA at Lagrange multiplier `s < 0` (trade-off slope); returns the
    /// converged (R, D) point. `iters` capped; convergence is monitored on
    /// the output marginal.
    pub fn solve_at_slope(&self, s: f64, iters: usize, tol: f64) -> RdPoint {
        assert!(s < 0.0, "slope must be negative");
        let (nx, ny) = (self.x.len(), self.y.len());
        // output marginal q(y), init uniform
        let mut q = vec![1.0 / ny as f64; ny];
        // A[i][j] = exp(s * d(i,j)) precomputed
        let a: Vec<Vec<f64>> = (0..nx)
            .map(|i| (0..ny).map(|j| (s * self.distortion(i, j)).exp()).collect())
            .collect();
        let mut w = vec![vec![0.0; ny]; nx]; // conditional P(y|x)
        for _ in 0..iters {
            // update conditionals
            for i in 0..nx {
                let mut z = 0.0;
                for j in 0..ny {
                    w[i][j] = q[j] * a[i][j];
                    z += w[i][j];
                }
                if z > 0.0 {
                    for j in 0..ny {
                        w[i][j] /= z;
                    }
                }
            }
            // update marginal
            let mut q_new = vec![0.0; ny];
            for i in 0..nx {
                for j in 0..ny {
                    q_new[j] += self.p[i] * w[i][j];
                }
            }
            let delta: f64 = q_new
                .iter()
                .zip(&q)
                .map(|(a, b)| (a - b).abs())
                .sum();
            q = q_new;
            if delta < tol {
                break;
            }
        }
        // evaluate R = I(X;Y), D = E[d]
        let mut rate = 0.0;
        let mut dist = 0.0;
        for i in 0..nx {
            for j in 0..ny {
                let pij = self.p[i] * w[i][j];
                if pij > 1e-300 && q[j] > 1e-300 {
                    rate += pij * (w[i][j] / q[j]).log2();
                }
                dist += pij * self.distortion(i, j);
            }
        }
        RdPoint { rate_bits: rate.max(0.0), distortion: dist }
    }

    /// Sweep slopes to trace D(R): returns points sorted by rate.
    pub fn sweep(&self, slopes: &[f64], iters: usize, tol: f64) -> Vec<RdPoint> {
        let mut pts: Vec<RdPoint> = slopes
            .iter()
            .map(|&s| self.solve_at_slope(s, iters, tol))
            .collect();
        pts.sort_by(|a, b| a.rate_bits.partial_cmp(&b.rate_bits).unwrap());
        pts
    }

    /// Interpolated D at a target rate from swept points.
    pub fn distortion_at_rate(pts: &[RdPoint], rate: f64) -> Option<f64> {
        if pts.is_empty() {
            return None;
        }
        if rate <= pts[0].rate_bits {
            return Some(pts[0].distortion);
        }
        for w in pts.windows(2) {
            if rate >= w[0].rate_bits && rate <= w[1].rate_bits {
                let span = w[1].rate_bits - w[0].rate_bits;
                if span < 1e-12 {
                    return Some(w[0].distortion);
                }
                let f = (rate - w[0].rate_bits) / span;
                return Some(w[0].distortion * (1.0 - f) + w[1].distortion * f);
            }
        }
        pts.last().map(|p| p.distortion)
    }

    /// Default slope grid covering ~0.2 .. ~8 bits for Exp sources: slopes
    /// are in units of 1/E[Θ], scaled by λ.
    pub fn default_slopes(lambda: f64) -> Vec<f64> {
        // s ≈ -λ * k: larger |s| => lower distortion => higher rate
        [
            0.35, 0.5, 0.75, 1.0, 1.5, 2.0, 3.0, 4.5, 7.0, 10.0, 16.0, 24.0, 40.0, 64.0, 100.0,
            160.0, 260.0,
        ]
        .iter()
        .map(|k| -lambda * k)
        .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::theory::rate_distortion::{d_lower, d_upper};

    fn ba() -> BlahutArimoto {
        BlahutArimoto::exponential(10.0, 240, 10.0)
    }

    #[test]
    fn masses_sum_to_one() {
        let b = ba();
        let total: f64 = b.p.iter().sum();
        assert!((total - 1.0).abs() < 1e-12);
    }

    #[test]
    fn sandwiched_by_analytic_bounds() {
        // the central Fig. 4 claim: D^L(R) <= D_BA(R) <= D^U(R)
        // (up to discretization slack at the low-rate end)
        // discretization makes the discrete-source D(R) dip below the
        // continuous Shannon bound once D approaches the bin width, so the
        // check is restricted to rates where bins are much finer than D
        let lam = 10.0;
        let b = BlahutArimoto::exponential(lam, 400, 12.0);
        let pts = b.sweep(&BlahutArimoto::default_slopes(lam), 400, 1e-9);
        let bin = 12.0 / lam / 400.0;
        for p in pts
            .iter()
            .filter(|p| p.rate_bits > 0.3 && p.distortion > 8.0 * bin)
        {
            let lo = d_lower(p.rate_bits, lam);
            let hi = d_upper(p.rate_bits, lam);
            assert!(
                p.distortion >= lo * 0.95,
                "BA below Shannon bound: R={} D={} lo={}",
                p.rate_bits,
                p.distortion,
                lo
            );
            assert!(
                p.distortion <= hi * 1.02,
                "BA above test-channel bound: R={} D={} hi={}",
                p.rate_bits,
                p.distortion,
                hi
            );
        }
    }

    #[test]
    fn curve_is_monotone_decreasing() {
        let lam = 10.0;
        let b = ba();
        let pts = b.sweep(&BlahutArimoto::default_slopes(lam), 300, 1e-8);
        for w in pts.windows(2) {
            assert!(
                w[1].distortion <= w[0].distortion + 1e-9,
                "D must fall as R grows: {w:?}"
            );
        }
    }

    #[test]
    fn steeper_slope_gives_higher_rate() {
        let b = ba();
        let lo = b.solve_at_slope(-5.0, 300, 1e-9);
        let hi = b.solve_at_slope(-80.0, 300, 1e-9);
        assert!(hi.rate_bits > lo.rate_bits);
        assert!(hi.distortion < lo.distortion);
    }

    #[test]
    fn interpolation_brackets() {
        let pts = vec![
            RdPoint { rate_bits: 1.0, distortion: 0.1 },
            RdPoint { rate_bits: 3.0, distortion: 0.02 },
        ];
        let mid = BlahutArimoto::distortion_at_rate(&pts, 2.0).unwrap();
        assert!((mid - 0.06).abs() < 1e-12);
    }
}
