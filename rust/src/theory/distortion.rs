//! Output-distortion propagation (paper §III).
//!
//! Prop. 3.1: for an L-layer FC DNN with 1-Lipschitz activations,
//! normalized input (‖x‖₁ <= 1) and per-layer quantization error
//! ‖W_l - Ŵ_l‖ <= τ_l,
//!
//!   ‖f(x, W) - f(x, Ŵ)‖₁ <= Σ_l A_l ‖W_l - Ŵ_l‖
//!   A_l = Π_{j<l} ‖W_j‖ · Π_{k>l} (‖W_k‖ + τ_k)
//!
//! with ‖·‖ the operator norm induced by ‖·‖₁ (max absolute column sum) —
//! the norm under which ‖Wx‖₁ <= ‖W‖‖x‖₁, which the proof's recursion
//! needs. The paper's surrogate metric (eq. 15) then *drops* the A_l and
//! uses the raw entrywise-L1 parameter distortion; `surrogate_l1` is that
//! metric, and Remark 3.2's first-order constant H is estimated
//! empirically in the Fig. 3 bench.

use crate::metrics::stats;
use crate::quant::mixed::BitAllocation;
use crate::quant::{quantize_magnitudes, Scheme};

/// One interface over the repo's distortion predictors (§III + §IV): map
/// a per-group [`BitAllocation`] to a scalar predicted distortion. The
/// mixed-precision allocator ([`crate::quant::mixed::allocate_bits`])
/// and the fleet objective compare predictions, so implementations only
/// need a consistent scale of their own — not a shared unit:
///
/// - [`crate::theory::rate_distortion::RateBoundModel`] — the analytic
///   Prop. 4.2 bound Σ w_g D^U(b_g - 1, λ_g) (per-parameter units).
/// - [`crate::quant::error::EmpiricalUniformModel`] — the numerically
///   integrated distortion of a *real* uniform quantizer per group.
/// - [`SurrogateModel`] — the paper's eq. 15 surrogate on actual weight
///   blobs, one blob per group (total-L1 units).
/// - [`OutputBoundModel`] — the Prop. 3.1 end-to-end output bound, one
///   layer per group (output-L1 units).
pub trait DistortionModel {
    /// Predicted distortion of quantizing at `alloc`'s per-group
    /// bit-widths. Must be monotone non-increasing in every group's
    /// bits for the greedy allocator's water-filling to be meaningful.
    fn predict(&self, alloc: &BitAllocation) -> f64;
}

/// A dense layer weight matrix, row-major, mapping x (cols) -> y (rows):
/// y = W x.
#[derive(Debug, Clone)]
pub struct LayerMatrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl LayerMatrix {
    pub fn new(rows: usize, cols: usize, data: Vec<f32>) -> LayerMatrix {
        assert_eq!(data.len(), rows * cols);
        LayerMatrix { rows, cols, data }
    }

    /// Operator norm induced by L1: max over columns of Σ_rows |w_rc|.
    pub fn induced_l1(&self) -> f64 {
        (0..self.cols)
            .map(|c| {
                (0..self.rows)
                    .map(|r| self.data[r * self.cols + c].abs() as f64)
                    .sum::<f64>()
            })
            .fold(0.0, f64::max)
    }

    /// Entrywise L1 (the paper's eq. 15 building block).
    pub fn entrywise_l1(&self) -> f64 {
        stats::l1(&self.data)
    }

    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.cols);
        (0..self.rows)
            .map(|r| {
                self.data[r * self.cols..(r + 1) * self.cols]
                    .iter()
                    .zip(x)
                    .map(|(w, xv)| *w as f64 * xv)
                    .sum()
            })
            .collect()
    }

    pub fn sub_l1_induced(&self, other: &LayerMatrix) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        (0..self.cols)
            .map(|c| {
                (0..self.rows)
                    .map(|r| {
                        let i = r * self.cols + c;
                        (self.data[i] - other.data[i]).abs() as f64
                    })
                    .sum::<f64>()
            })
            .fold(0.0, f64::max)
    }

    pub fn sub_l1_entrywise(&self, other: &LayerMatrix) -> f64 {
        stats::l1_dist(&self.data, &other.data)
    }
}

/// ReLU FC net forward (the Prop. 3.1 model class, eq. 10: activation on
/// all but the last layer).
pub fn fc_forward(layers: &[LayerMatrix], x: &[f64]) -> Vec<f64> {
    let mut h = x.to_vec();
    for (i, w) in layers.iter().enumerate() {
        h = w.matvec(&h);
        if i + 1 < layers.len() {
            for v in &mut h {
                *v = v.max(0.0);
            }
        }
    }
    h
}

/// The Prop. 3.1 coefficients A_l (eq. 14), in induced-L1 norm.
pub fn coefficients(orig: &[LayerMatrix], quant: &[LayerMatrix]) -> Vec<f64> {
    assert_eq!(orig.len(), quant.len());
    let l = orig.len();
    let norms: Vec<f64> = orig.iter().map(LayerMatrix::induced_l1).collect();
    let taus: Vec<f64> = orig
        .iter()
        .zip(quant)
        .map(|(w, wq)| w.sub_l1_induced(wq))
        .collect();
    (0..l)
        .map(|i| {
            let prefix: f64 = norms[..i].iter().product();
            let suffix: f64 = (i + 1..l).map(|k| norms[k] + taus[k]).product();
            prefix * suffix
        })
        .collect()
}

/// Prop. 3.1 upper bound on ‖f(x,W) - f(x,Ŵ)‖₁ for any ‖x‖₁ <= 1.
pub fn output_distortion_bound(orig: &[LayerMatrix], quant: &[LayerMatrix]) -> f64 {
    let a = coefficients(orig, quant);
    orig.iter()
        .zip(quant)
        .zip(a)
        .map(|((w, wq), ai)| ai * w.sub_l1_induced(wq))
        .sum()
}

/// The paper's surrogate metric (eq. 15): total entrywise-L1 parameter
/// distortion, the quantity the rate–distortion analysis of §IV bounds.
pub fn surrogate_l1(orig: &[LayerMatrix], quant: &[LayerMatrix]) -> f64 {
    orig.iter()
        .zip(quant)
        .map(|(w, wq)| w.sub_l1_entrywise(wq))
        .sum()
}

/// Surrogate for flat weight blobs (transformer LAIMs, Remark 3.2): the
/// runtime path — per-parameter mean absolute perturbation.
pub fn surrogate_l1_flat(orig: &[f32], quant: &[f32]) -> f64 {
    stats::l1_dist(orig, quant)
}

/// [`DistortionModel`] over the eq. 15 surrogate: one flat weight blob
/// per allocation group, each quantized at its group's bit-width with
/// the configured scheme; predicts the summed entrywise-L1 distortion.
#[derive(Debug, Clone)]
pub struct SurrogateModel {
    groups: Vec<Vec<f32>>,
    scheme: Scheme,
}

impl SurrogateModel {
    pub fn new(groups: Vec<Vec<f32>>, scheme: Scheme) -> SurrogateModel {
        assert!(!groups.is_empty() && groups.iter().all(|g| !g.is_empty()));
        SurrogateModel { groups, scheme }
    }
}

impl DistortionModel for SurrogateModel {
    fn predict(&self, alloc: &BitAllocation) -> f64 {
        assert_eq!(alloc.len(), self.groups.len(), "allocation/group count mismatch");
        alloc
            .groups()
            .zip(&self.groups)
            .map(|((bits, _, _), blob)| {
                let q = quantize_magnitudes(blob, bits, self.scheme);
                surrogate_l1_flat(blob, &q)
            })
            .sum()
    }
}

/// [`DistortionModel`] over the Prop. 3.1 output bound: one layer per
/// allocation group; predicts the end-to-end output-L1 bound of
/// quantizing layer g at b_g bits.
#[derive(Debug, Clone)]
pub struct OutputBoundModel {
    layers: Vec<LayerMatrix>,
    scheme: Scheme,
}

impl OutputBoundModel {
    pub fn new(layers: Vec<LayerMatrix>, scheme: Scheme) -> OutputBoundModel {
        assert!(!layers.is_empty());
        OutputBoundModel { layers, scheme }
    }
}

impl DistortionModel for OutputBoundModel {
    fn predict(&self, alloc: &BitAllocation) -> f64 {
        assert_eq!(alloc.len(), self.layers.len(), "allocation/layer count mismatch");
        let quant: Vec<LayerMatrix> = alloc
            .groups()
            .zip(&self.layers)
            .map(|((bits, _, _), w)| {
                LayerMatrix::new(w.rows, w.cols, quantize_magnitudes(&w.data, bits, self.scheme))
            })
            .collect();
        output_distortion_bound(&self.layers, &quant)
    }
}

/// Empirical first-order constant H of Remark 3.2: given measured
/// (param_distortion, output_distortion) pairs, the smallest H with
/// output <= H * param over all pairs.
pub fn empirical_h(pairs: &[(f64, f64)]) -> f64 {
    pairs
        .iter()
        .filter(|(p, _)| *p > 0.0)
        .map(|(p, o)| o / p)
        .fold(0.0, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{quantize_magnitudes, Scheme};
    use crate::util::prop::forall;
    use crate::util::rng::Rng;

    fn random_net(rng: &mut Rng, dims: &[usize], scale: f64) -> Vec<LayerMatrix> {
        dims.windows(2)
            .map(|w| {
                let (ci, co) = (w[0], w[1]);
                LayerMatrix::new(
                    co,
                    ci,
                    (0..ci * co).map(|_| (scale * rng.normal()) as f32).collect(),
                )
            })
            .collect()
    }

    fn quantize_net(net: &[LayerMatrix], bits: u32, scheme: Scheme) -> Vec<LayerMatrix> {
        net.iter()
            .map(|w| LayerMatrix::new(w.rows, w.cols, quantize_magnitudes(&w.data, bits, scheme)))
            .collect()
    }

    #[test]
    fn induced_norm_known_matrix() {
        // columns sums: |1|+|3| = 4, |-2|+|4| = 6
        let m = LayerMatrix::new(2, 2, vec![1.0, -2.0, 3.0, 4.0]);
        assert_eq!(m.induced_l1(), 6.0);
        assert_eq!(m.entrywise_l1(), 10.0);
    }

    #[test]
    fn induced_norm_is_matvec_gain_bound() {
        forall(
            "‖Wx‖1 <= ‖W‖ ‖x‖1",
            100,
            |r| {
                let rows = 2 + r.below(6);
                let cols = 2 + r.below(6);
                let data: Vec<f32> = (0..rows * cols).map(|_| r.normal() as f32).collect();
                let x: Vec<f64> = (0..cols).map(|_| r.normal()).collect();
                (rows, cols, data, x)
            },
            |(rows, cols, data, x)| {
                let m = LayerMatrix::new(*rows, *cols, data.clone());
                let y = m.matvec(x);
                let y1: f64 = y.iter().map(|v| v.abs()).sum();
                let x1: f64 = x.iter().map(|v| v.abs()).sum();
                if y1 <= m.induced_l1() * x1 + 1e-9 {
                    Ok(())
                } else {
                    Err(format!("{y1} > {} * {x1}", m.induced_l1()))
                }
            },
        );
    }

    /// The core Prop. 3.1 property: bound dominates the true output
    /// distortion for random FC ReLU nets under real quantizers.
    #[test]
    fn prop31_bound_dominates_true_distortion() {
        forall(
            "Prop 3.1 dominance",
            60,
            |r| {
                let depth = 2 + r.below(3);
                let mut dims = vec![4 + r.below(5)];
                for _ in 0..depth {
                    dims.push(3 + r.below(6));
                }
                let bits = 2 + r.below(6) as u32;
                let scheme = if r.f64() < 0.5 { Scheme::Uniform } else { Scheme::Pot };
                let seed = r.next_u64();
                (dims, bits, scheme, seed)
            },
            |(dims, bits, scheme, seed)| {
                let mut rng = Rng::new(*seed);
                let net = random_net(&mut rng, dims, 0.4);
                let qnet = quantize_net(&net, *bits, *scheme);
                // normalized input: ‖x‖1 = 1
                let mut x: Vec<f64> = (0..dims[0]).map(|_| rng.normal()).collect();
                let n1: f64 = x.iter().map(|v| v.abs()).sum();
                for v in &mut x {
                    *v /= n1;
                }
                let y = fc_forward(&net, &x);
                let yq = fc_forward(&qnet, &x);
                let true_dist: f64 = y.iter().zip(&yq).map(|(a, b)| (a - b).abs()).sum();
                let bound = output_distortion_bound(&net, &qnet);
                if true_dist <= bound + 1e-9 {
                    Ok(())
                } else {
                    Err(format!("true {true_dist} > bound {bound}"))
                }
            },
        );
    }

    #[test]
    fn bound_shrinks_with_more_bits() {
        let mut rng = Rng::new(5);
        let net = random_net(&mut rng, &[8, 16, 16, 4], 0.3);
        let bounds: Vec<f64> = (2..=8)
            .map(|b| {
                let q = quantize_net(&net, b, Scheme::Uniform);
                output_distortion_bound(&net, &q)
            })
            .collect();
        // monotone up to fp noise
        for w in bounds.windows(2) {
            assert!(w[1] <= w[0] * 1.001, "{bounds:?}");
        }
        assert!(bounds.last().unwrap() < &(bounds[0] * 0.1));
    }

    #[test]
    fn identical_nets_have_zero_distortion_and_bound() {
        let mut rng = Rng::new(6);
        let net = random_net(&mut rng, &[5, 7, 3], 0.5);
        assert_eq!(output_distortion_bound(&net, &net.clone()), 0.0);
        assert_eq!(surrogate_l1(&net, &net.clone()), 0.0);
    }

    #[test]
    fn distortion_models_are_monotone_in_group_bits() {
        let mut rng = Rng::new(41);
        let net = random_net(&mut rng, &[6, 8, 8, 4], 0.3);
        let blobs: Vec<Vec<f32>> = net.iter().map(|w| w.data.clone()).collect();
        let lambdas: Vec<f64> = blobs
            .iter()
            .map(|b| crate::theory::expdist::ExponentialModel::fit_weights(b).lambda)
            .collect();
        let weights = vec![1.0; blobs.len()];
        let surrogate = SurrogateModel::new(blobs, Scheme::Uniform);
        let output = OutputBoundModel::new(net, Scheme::Uniform);
        let models: [&dyn DistortionModel; 2] = [&surrogate, &output];
        for model in models {
            let mut prev = f64::INFINITY;
            for bits in 2..=8u32 {
                let alloc = BitAllocation::new(
                    &vec![bits; lambdas.len()],
                    &lambdas,
                    &weights,
                )
                .unwrap();
                let d = model.predict(&alloc);
                assert!(d <= prev * 1.001 + 1e-12, "bits {bits}: {d} > {prev}");
                prev = d;
            }
        }
    }

    #[test]
    fn surrogate_model_matches_free_fn_sum() {
        let mut rng = Rng::new(42);
        let blobs: Vec<Vec<f32>> = (0..3)
            .map(|_| (0..256).map(|_| (0.2 * rng.normal()) as f32).collect())
            .collect();
        let model = SurrogateModel::new(blobs.clone(), Scheme::Pot);
        let alloc =
            BitAllocation::new(&[3, 5, 7], &[1.0, 1.0, 1.0], &[1.0, 1.0, 1.0]).unwrap();
        let expected: f64 = blobs
            .iter()
            .zip([3u32, 5, 7])
            .map(|(b, bits)| {
                surrogate_l1_flat(b, &quantize_magnitudes(b, bits, Scheme::Pot))
            })
            .sum();
        assert_eq!(model.predict(&alloc), expected);
    }

    #[test]
    fn empirical_h_bounds_all_pairs() {
        let pairs = vec![(1.0, 2.0), (2.0, 3.0), (4.0, 10.0)];
        let h = empirical_h(&pairs);
        assert!((h - 2.5).abs() < 1e-12);
        assert!(pairs.iter().all(|(p, o)| *o <= h * p + 1e-12));
    }
}
