//! Exponential model of LAIM parameter magnitudes (paper §II-C, eq. 3):
//!
//!   P_Θ(θ) = λ e^{-λθ},  θ >= 0
//!
//! with MLE fitting from weight blobs, the differential entropy
//! h(Θ) = log2(e/λ) (eq. 21), and a KS goodness-of-fit check backing the
//! Fig. 2 claim that pre-trained weights are well-modeled by (3).

use crate::metrics::stats;

pub const LN2: f64 = std::f64::consts::LN_2;

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ExponentialModel {
    pub lambda: f64,
}

impl ExponentialModel {
    pub fn new(lambda: f64) -> ExponentialModel {
        assert!(lambda > 0.0, "lambda must be positive");
        ExponentialModel { lambda }
    }

    /// MLE fit from parameter magnitudes: λ* = 1 / mean(|θ|).
    /// Exact zeros are kept (they carry mass near 0 consistently with the
    /// sharp peak the paper observes).
    pub fn fit(magnitudes: impl IntoIterator<Item = f64>) -> ExponentialModel {
        let mut sum = 0.0;
        let mut n = 0usize;
        for m in magnitudes {
            debug_assert!(m >= 0.0);
            sum += m;
            n += 1;
        }
        assert!(n > 0, "cannot fit on empty data");
        ExponentialModel::new((n as f64 / sum).min(1e12))
    }

    /// Fit from an f32 weight blob (signs stripped).
    pub fn fit_weights(weights: &[f32]) -> ExponentialModel {
        Self::fit(weights.iter().map(|w| w.abs() as f64))
    }

    /// Per-group MLE fits over `n_groups` contiguous channel groups of a
    /// flat weight blob — the calibration step of mixed-precision
    /// allocation (QVLA: channel groups have visibly different λ, which
    /// is exactly the spread the per-group bit allocator exploits).
    /// Group g covers `[g·n/n_groups, (g+1)·n/n_groups)`.
    pub fn fit_channel_groups(weights: &[f32], n_groups: usize) -> Vec<ExponentialModel> {
        assert!(n_groups >= 1, "need at least one group");
        assert!(weights.len() >= n_groups, "fewer weights than groups");
        let n = weights.len();
        (0..n_groups)
            .map(|g| Self::fit_weights(&weights[g * n / n_groups..(g + 1) * n / n_groups]))
            .collect()
    }

    pub fn pdf(&self, theta: f64) -> f64 {
        if theta < 0.0 {
            0.0
        } else {
            self.lambda * (-self.lambda * theta).exp()
        }
    }

    pub fn cdf(&self, theta: f64) -> f64 {
        if theta < 0.0 {
            0.0
        } else {
            1.0 - (-self.lambda * theta).exp()
        }
    }

    pub fn mean(&self) -> f64 {
        1.0 / self.lambda
    }

    /// Differential entropy in bits (eq. 21): h(Θ) = log2(e/λ).
    pub fn differential_entropy_bits(&self) -> f64 {
        (std::f64::consts::E / self.lambda).log2()
    }

    /// KS statistic of data against this model (Fig. 2 support).
    pub fn ks_statistic(&self, magnitudes: &[f64]) -> f64 {
        stats::ks_statistic(magnitudes, |x| self.cdf(x))
    }

    /// Inverse-CDF sampling hook for simulation.
    pub fn sample(&self, rng: &mut crate::util::rng::Rng) -> f64 {
        rng.exponential(self.lambda)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;
    use crate::util::rng::Rng;

    #[test]
    fn fit_recovers_lambda() {
        let mut rng = Rng::new(0);
        let truth = 37.5;
        let xs: Vec<f64> = (0..100_000).map(|_| rng.exponential(truth)).collect();
        let model = ExponentialModel::fit(xs.iter().copied());
        assert!((model.lambda - truth).abs() / truth < 0.02, "{}", model.lambda);
    }

    #[test]
    fn channel_group_fits_recover_per_group_lambdas() {
        let mut rng = Rng::new(3);
        let truths = [5.0, 40.0, 160.0];
        let mut blob = Vec::new();
        for t in truths {
            for _ in 0..50_000 {
                blob.push(rng.exponential(t) as f32);
            }
        }
        let models = ExponentialModel::fit_channel_groups(&blob, 3);
        assert_eq!(models.len(), 3);
        for (m, t) in models.iter().zip(truths) {
            assert!((m.lambda - t).abs() / t < 0.03, "{} vs {t}", m.lambda);
        }
        // one group collapses to the pooled fit
        let pooled = ExponentialModel::fit_channel_groups(&blob, 1);
        assert_eq!(pooled[0], ExponentialModel::fit_weights(&blob));
    }

    #[test]
    fn entropy_closed_form_matches_numeric_integration() {
        let m = ExponentialModel::new(5.0);
        // -∫ p log2 p over a fine grid
        let mut h = 0.0;
        let dx = 1e-4;
        let mut x = dx / 2.0;
        while x < 10.0 {
            let p = m.pdf(x);
            if p > 0.0 {
                h -= p * p.log2() * dx;
            }
            x += dx;
        }
        assert!((h - m.differential_entropy_bits()).abs() < 1e-3, "{h}");
    }

    #[test]
    fn cdf_properties() {
        forall(
            "exp cdf in [0,1] and monotone",
            200,
            |r| (r.range(0.1, 100.0), r.range(0.0, 5.0), r.range(0.0, 5.0)),
            |&(lam, a, b)| {
                let m = ExponentialModel::new(lam);
                let (lo, hi) = if a < b { (a, b) } else { (b, a) };
                let (ca, cb) = (m.cdf(lo), m.cdf(hi));
                if !(0.0..=1.0).contains(&ca) || !(0.0..=1.0).contains(&cb) {
                    return Err(format!("cdf out of range: {ca} {cb}"));
                }
                if cb < ca {
                    return Err("cdf not monotone".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn larger_lambda_means_lower_entropy() {
        // sharper peak at zero => easier to quantize (Remark 4.1)
        let h1 = ExponentialModel::new(1.0).differential_entropy_bits();
        let h2 = ExponentialModel::new(100.0).differential_entropy_bits();
        assert!(h2 < h1);
    }

    #[test]
    fn ks_accepts_own_samples() {
        let mut rng = Rng::new(9);
        let m = ExponentialModel::new(12.0);
        let xs: Vec<f64> = (0..20_000).map(|_| m.sample(&mut rng)).collect();
        assert!(m.ks_statistic(&xs) < 0.02);
    }
}
