//! The paper's analytical core (§III–IV): exponential parameter-magnitude
//! modeling, the quantization rate–distortion bounds, the Blahut–Arimoto
//! numerical reference, and the Prop. 3.1 output-distortion propagation
//! bound.

pub mod blahut_arimoto;
pub mod distortion;
pub mod expdist;
pub mod rate_distortion;
