//! Rate–distortion bounds for sign-preserving magnitude quantization
//! (paper §IV, Props. 4.1 & 4.2) under the exponential source (eq. 3) and
//! absolute-error distortion d(θ, θ̂) = |θ - θ̂|.
//!
//! Lower bound (Shannon-type, Prop. 4.1):
//!   R(D) >= -log2(2 λ D)            D(R) >= 1 / (λ 2^{R+1})
//! Upper bound (Laplacian test channel, Prop. 4.2):
//!   R(D) <= log2( 1/(λD) + λD/(λD+1) )
//!   D(R) <= (1/2λ) ( sqrt(1 + 4/(2^R - 1)) - 1 )
//!
//! Conventions: rates in bits/parameter; a total bit-width b̂ spends one
//! bit on the sign, so the magnitude rate is R = b̂ - 1 — which is exactly
//! why the paper's objective (P1) evaluates the bounds at b̂ - 1.

/// Prop. 4.1: D^L(R) — optimistic distortion floor.
pub fn d_lower(rate_bits: f64, lambda: f64) -> f64 {
    assert!(lambda > 0.0);
    1.0 / (lambda * 2f64.powf(rate_bits + 1.0))
}

/// Prop. 4.1: R^L(D).
pub fn r_lower(d: f64, lambda: f64) -> f64 {
    assert!(d > 0.0 && lambda > 0.0);
    -(2.0 * lambda * d).log2()
}

/// Prop. 4.2: D^U(R) — conservative distortion estimate. Only defined for
/// R > 0 (a zero-rate magnitude code carries no information); returns the
/// source's E[Θ] = 1/λ at R <= 0, the distortion of reconstructing with 0.
pub fn d_upper(rate_bits: f64, lambda: f64) -> f64 {
    assert!(lambda > 0.0);
    if rate_bits <= 0.0 {
        return 1.0 / lambda;
    }
    let t = 4.0 / (2f64.powf(rate_bits) - 1.0);
    ((1.0 + t).sqrt() - 1.0) / (2.0 * lambda)
}

/// Prop. 4.2: R^U(D).
pub fn r_upper(d: f64, lambda: f64) -> f64 {
    assert!(d > 0.0 && lambda > 0.0);
    let ld = lambda * d;
    (1.0 / ld + ld / (ld + 1.0)).log2()
}

/// Eq. (29): E[|Θ + Z|] for Θ ~ Exp(λ), Z ~ Laplace(E|Z| = d) independent.
/// Used to cross-check Prop. 4.2's derivation numerically.
pub fn e_abs_theta_plus_z(lambda: f64, d: f64) -> f64 {
    1.0 / lambda + d * (lambda * d) / (lambda * d + 1.0)
}

/// The paper's (P1) objective: the bound gap at total bit-width b̂,
/// D^U(b̂-1) - D^L(b̂-1). Minimizing it both pushes the conservative
/// estimate down and certifies tightness.
pub fn bound_gap(b_hat: f64, lambda: f64) -> f64 {
    d_upper(b_hat - 1.0, lambda) - d_lower(b_hat - 1.0, lambda)
}

/// The analytic [`DistortionModel`]: group-decomposed Prop. 4.2 bound
/// Σ_g w_g D^U(b_g - 1, λ_g). This is what the fleet objective and the
/// default mixed-precision allocator optimize — no weight blobs needed,
/// only the fitted per-group λ the allocation already carries.
#[derive(Debug, Clone, Copy, Default)]
pub struct RateBoundModel;

impl crate::theory::distortion::DistortionModel for RateBoundModel {
    fn predict(&self, alloc: &crate::quant::mixed::BitAllocation) -> f64 {
        alloc.d_upper_total()
    }
}

/// SCA surrogate pieces (§V-B, eq. 33/34): the linear lower bound of
/// D^L(b̃-1) = 1/(λ 2^{b̃}) around b_k, and the resulting convex
/// majorant ζ̄ of the objective.
pub fn zeta_lower_linear(b_tilde: f64, b_k: f64, lambda: f64) -> f64 {
    let base = 1.0 / (lambda * 2f64.powf(b_k));
    base - (std::f64::consts::LN_2 * base) * (b_tilde - b_k)
}

pub fn zeta_bar(b_tilde: f64, b_k: f64, lambda: f64) -> f64 {
    d_upper(b_tilde - 1.0, lambda) - zeta_lower_linear(b_tilde, b_k, lambda)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;
    use crate::util::rng::Rng;

    #[test]
    fn lower_below_upper_everywhere() {
        forall(
            "D^L <= D^U",
            500,
            |r| (r.range(0.25, 16.0), r.range(0.05, 500.0)),
            |&(rate, lam)| {
                let (lo, hi) = (d_lower(rate, lam), d_upper(rate, lam));
                if lo <= hi {
                    Ok(())
                } else {
                    Err(format!("D^L {lo} > D^U {hi}"))
                }
            },
        );
    }

    #[test]
    fn bounds_decrease_in_rate() {
        forall(
            "D(R) bounds monotone decreasing",
            300,
            |r| (r.range(0.1, 12.0), r.range(0.05, 3.0), r.range(0.1, 200.0)),
            |&(rate, dr, lam)| {
                if d_lower(rate + dr, lam) < d_lower(rate, lam)
                    && d_upper(rate + dr, lam) <= d_upper(rate, lam)
                {
                    Ok(())
                } else {
                    Err("not monotone".into())
                }
            },
        );
    }

    #[test]
    fn bounds_scale_inversely_with_lambda() {
        // Remark 4.1: sharper weight concentration (larger λ) => less
        // distortion at the same rate
        forall(
            "D ~ 1/lambda",
            200,
            |r| (r.range(0.5, 10.0), r.range(0.1, 100.0)),
            |&(rate, lam)| {
                let ratio_l = d_lower(rate, lam) / d_lower(rate, 2.0 * lam);
                let ratio_u = d_upper(rate, lam) / d_upper(rate, 2.0 * lam);
                if (ratio_l - 2.0).abs() < 1e-9 && (ratio_u - 2.0).abs() < 1e-9 {
                    Ok(())
                } else {
                    Err(format!("ratios {ratio_l} {ratio_u}"))
                }
            },
        );
    }

    #[test]
    fn rate_and_distortion_forms_are_inverses() {
        forall(
            "R^L and D^L invert",
            200,
            |r| (r.range(0.5, 10.0), r.range(0.1, 100.0)),
            |&(rate, lam)| {
                let d = d_lower(rate, lam);
                let back = r_lower(d, lam);
                if (back - rate).abs() < 1e-9 {
                    Ok(())
                } else {
                    Err(format!("{rate} -> {d} -> {back}"))
                }
            },
        );
        // R^U(D^U(R)) = R as well (the upper pair is derived by inversion)
        forall(
            "R^U and D^U invert",
            200,
            |r| (r.range(0.5, 10.0), r.range(0.1, 100.0)),
            |&(rate, lam)| {
                let d = d_upper(rate, lam);
                let back = r_upper(d, lam);
                if (back - rate).abs() < 1e-6 {
                    Ok(())
                } else {
                    Err(format!("{rate} -> {d} -> {back}"))
                }
            },
        );
    }

    #[test]
    fn e_abs_matches_monte_carlo() {
        // eq. (29) against simulation
        let mut rng = Rng::new(11);
        let (lam, d) = (8.0, 0.05);
        let n = 400_000;
        let mc: f64 = (0..n)
            .map(|_| (rng.exponential(lam) + rng.laplace(d)).abs())
            .sum::<f64>()
            / n as f64;
        let closed = e_abs_theta_plus_z(lam, d);
        assert!((mc - closed).abs() / closed < 0.01, "mc {mc} closed {closed}");
    }

    #[test]
    fn shannon_lower_bound_equals_entropy_difference() {
        // R^L(D) = h(Θ) - log2(2eD)  (Lemma 4.1 + 4.2)
        let lam = 4.0;
        let d = 0.03;
        let h = crate::theory::expdist::ExponentialModel::new(lam)
            .differential_entropy_bits();
        let via_lemma = h - (2.0 * std::f64::consts::E * d).log2();
        assert!((r_lower(d, lam) - via_lemma).abs() < 1e-9);
    }

    #[test]
    fn bound_gap_shrinks_with_bits() {
        let lam = 20.0;
        let gaps: Vec<f64> = (2..=8).map(|b| bound_gap(b as f64, lam)).collect();
        assert!(gaps.windows(2).all(|w| w[1] < w[0]), "{gaps:?}");
    }

    #[test]
    fn zeta_bar_majorizes_objective_and_is_tight_at_expansion_point() {
        let lam = 15.0;
        let b_k = 5.0;
        // tight at b_k
        let at_k = zeta_bar(b_k, b_k, lam);
        assert!((at_k - bound_gap(b_k, lam)).abs() < 1e-12);
        // majorizes elsewhere (eq. 34)
        for b in [2.0, 3.0, 4.5, 6.0, 7.5, 10.0] {
            assert!(
                zeta_bar(b, b_k, lam) >= bound_gap(b, lam) - 1e-12,
                "b={b}"
            );
        }
    }

    #[test]
    fn zero_rate_upper_bound_is_source_mean() {
        assert_eq!(d_upper(0.0, 4.0), 0.25);
    }
}
