//! Tiny CLI argument parser (clap stand-in): subcommands, `--key value`,
//! `--key=value`, boolean flags, typed getters with defaults, and
//! auto-generated usage text. Also home of [`ParseError`], the shared
//! error type for every CLI-facing enum/token parser.

use std::collections::BTreeMap;

/// Error from a CLI-facing token parser (`FleetAlgorithm::parse`,
/// `DeviceProfile::parse`, `QueueDiscipline::parse`, ...): carries the
/// offending token plus the full list of valid choices, so the CLI can
/// print an actionable message instead of a bare "unknown value".
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ParseError {
    /// What was being parsed, e.g. `"fleet algorithm"`.
    pub what: &'static str,
    /// The token that failed to parse, verbatim.
    pub token: String,
    /// The accepted spellings (canonical names; aliases may also parse).
    pub choices: &'static [&'static str],
}

impl ParseError {
    pub fn new(what: &'static str, token: &str, choices: &'static [&'static str]) -> Self {
        ParseError { what, token: token.to_string(), choices }
    }
}

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "unknown {} \"{}\" (expected one of: {})",
            self.what,
            self.token,
            self.choices.join(" | ")
        )
    }
}

impl std::error::Error for ParseError {}

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
    spec: Vec<OptSpec>,
}

#[derive(Debug, Clone)]
struct OptSpec {
    name: String,
    help: String,
    default: Option<String>,
}

impl Args {
    /// Parse `std::env::args()` (skipping argv[0]). The first non-flag token
    /// becomes the subcommand; later non-flag tokens are positional.
    pub fn parse_env() -> Args {
        Self::parse(std::env::args().skip(1))
    }

    pub fn parse(tokens: impl IntoIterator<Item = String>) -> Args {
        let mut args = Args::default();
        let mut iter = tokens.into_iter().peekable();
        while let Some(tok) = iter.next() {
            if let Some(body) = tok.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    args.flags.insert(k.to_string(), v.to_string());
                } else {
                    // `--flag value` unless next token is another flag
                    match iter.peek() {
                        Some(nxt) if !nxt.starts_with("--") => {
                            let v = iter.next().unwrap();
                            args.flags.insert(body.to_string(), v);
                        }
                        _ => {
                            args.flags.insert(body.to_string(), "true".into());
                        }
                    }
                }
            } else if args.subcommand.is_none() {
                args.subcommand = Some(tok);
            } else {
                args.positional.push(tok);
            }
        }
        args
    }

    /// Register an option for `usage()`; returns self for chaining.
    pub fn describe(mut self, name: &str, help: &str, default: Option<&str>) -> Args {
        self.spec.push(OptSpec {
            name: name.to_string(),
            help: help.to_string(),
            default: default.map(str::to_string),
        });
        self
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    pub fn str(&self, key: &str, default: &str) -> String {
        self.flags.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    pub fn opt_str(&self, key: &str) -> Option<String> {
        self.flags.get(key).cloned()
    }

    pub fn f64(&self, key: &str, default: f64) -> f64 {
        self.flags
            .get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn usize(&self, key: &str, default: usize) -> usize {
        self.flags
            .get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn bool(&self, key: &str, default: bool) -> bool {
        match self.flags.get(key).map(String::as_str) {
            Some("true") | Some("1") | Some("yes") => true,
            Some("false") | Some("0") | Some("no") => false,
            Some(_) | None => default,
        }
    }

    /// Keys the user passed that were never described — catches typos.
    pub fn unknown_keys(&self) -> Vec<&str> {
        self.flags
            .keys()
            .filter(|k| !self.spec.iter().any(|s| &s.name == *k))
            .map(String::as_str)
            .collect()
    }

    pub fn usage(&self, program: &str, about: &str) -> String {
        let mut out = format!("{program} — {about}\n\noptions:\n");
        for s in &self.spec {
            let def = s
                .default
                .as_deref()
                .map(|d| format!(" [default: {d}]"))
                .unwrap_or_default();
            out.push_str(&format!("  --{:<24} {}{}\n", s.name, s.help, def));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toks(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(str::to_string))
    }

    #[test]
    fn subcommand_and_positional() {
        let a = toks("serve extra1 extra2");
        assert_eq!(a.subcommand.as_deref(), Some("serve"));
        assert_eq!(a.positional, vec!["extra1", "extra2"]);
    }

    #[test]
    fn key_value_both_syntaxes() {
        let a = toks("run --t0 3.5 --e0=2.0 --verbose");
        assert_eq!(a.f64("t0", 0.0), 3.5);
        assert_eq!(a.f64("e0", 0.0), 2.0);
        assert!(a.bool("verbose", false));
    }

    #[test]
    fn flag_before_another_flag_is_boolean() {
        let a = toks("--fast --steps 10");
        assert!(a.bool("fast", false));
        assert_eq!(a.usize("steps", 0), 10);
    }

    #[test]
    fn defaults_apply() {
        let a = toks("serve");
        assert_eq!(a.str("model", "blip2ish"), "blip2ish");
        assert_eq!(a.usize("batch", 4), 4);
    }

    #[test]
    fn unknown_key_detection() {
        let a = toks("--stpes 10").describe("steps", "step count", Some("100"));
        assert_eq!(a.unknown_keys(), vec!["stpes"]);
    }

    #[test]
    fn parse_error_names_token_and_choices() {
        let e = ParseError::new("fleet algorithm", "bogus", &["proposed", "equal", "random"]);
        assert_eq!(
            e.to_string(),
            "unknown fleet algorithm \"bogus\" (expected one of: proposed | equal | random)"
        );
    }
}
