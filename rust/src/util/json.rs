//! Minimal-but-complete JSON: recursive-descent parser + serializer.
//!
//! Covers everything the artifact manifest, golden vectors, config files
//! and telemetry dumps need: full escape handling, \uXXXX (incl. surrogate
//! pairs), nested containers, f64 numbers. Object key order is preserved
//! (insertion order), which keeps serialized telemetry diffs stable.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

#[derive(Debug, Clone, PartialEq)]
pub struct ParseError {
    pub msg: String,
    pub offset: usize,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for ParseError {}

impl Json {
    // ---- accessors -------------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().filter(|n| *n >= 0.0 && n.fract() == 0.0).map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Object field lookup; `None` for non-objects / missing keys.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(kv) => kv.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// Path lookup: `j.at(&["models", "blip2ish", "agent"])`.
    pub fn at(&self, path: &[&str]) -> Option<&Json> {
        path.iter().try_fold(self, |j, k| j.get(k))
    }

    pub fn keys(&self) -> Vec<&str> {
        match self {
            Json::Obj(kv) => kv.iter().map(|(k, _)| k.as_str()).collect(),
            _ => Vec::new(),
        }
    }

    // ---- builders --------------------------------------------------------

    pub fn obj() -> Json {
        Json::Obj(Vec::new())
    }

    pub fn set(mut self, key: &str, val: impl Into<Json>) -> Json {
        if let Json::Obj(ref mut kv) = self {
            kv.push((key.to_string(), val.into()));
        }
        self
    }

    // ---- serialization ---------------------------------------------------

    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(1), 0);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        let pad = |out: &mut String, d: usize| {
            if let Some(w) = indent {
                out.push('\n');
                for _ in 0..(w * d) {
                    out.push(' ');
                }
            }
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                if !a.is_empty() {
                    pad(out, depth);
                }
                out.push(']');
            }
            Json::Obj(kv) => {
                out.push('{');
                for (i, (k, v)) in kv.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !kv.is_empty() {
                    pad(out, depth);
                }
                out.push('}');
            }
        }
    }
}

impl From<f64> for Json {
    fn from(n: f64) -> Json {
        Json::Num(n)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Json {
        Json::Num(n as f64)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}
impl From<BTreeMap<String, f64>> for Json {
    fn from(m: BTreeMap<String, f64>) -> Json {
        Json::Obj(m.into_iter().map(|(k, v)| (k, Json::Num(v))).collect())
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

// ---------------------------------------------------------------------------
// parser
// ---------------------------------------------------------------------------

pub fn parse(input: &str) -> Result<Json, ParseError> {
    let mut p = Parser { bytes: input.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing data"));
    }
    Ok(v)
}

/// Parse a JSON file from disk.
pub fn parse_file(path: &std::path::Path) -> anyhow::Result<Json> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
    parse(&text).map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { msg: msg.to_string(), offset: self.pos }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), ParseError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, val: Json) -> Result<Json, ParseError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(val)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            out.push((key, val));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'u') => {
                            self.pos += 1;
                            let hi = self.hex4()?;
                            let c = if (0xD800..0xDC00).contains(&hi) {
                                // surrogate pair
                                if self.bytes[self.pos..].starts_with(b"\\u") {
                                    self.pos += 2;
                                    let lo = self.hex4()?;
                                    let code = 0x10000
                                        + ((hi - 0xD800) << 10)
                                        + (lo - 0xDC00);
                                    char::from_u32(code)
                                } else {
                                    None
                                }
                            } else {
                                char::from_u32(hi)
                            };
                            out.push(c.ok_or_else(|| self.err("bad \\u escape"))?);
                            continue; // hex4 advanced pos already
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let rest = &self.bytes[self.pos..];
                    let s = std::str::from_utf8(rest)
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, ParseError> {
        if self.pos + 4 > self.bytes.len() {
            return Err(self.err("truncated \\u escape"));
        }
        let s = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
            .map_err(|_| self.err("bad hex"))?;
        let v = u32::from_str_radix(s, 16).map_err(|_| self.err("bad hex"))?;
        self.pos += 4;
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("-12.5e2").unwrap(), Json::Num(-1250.0));
        assert_eq!(parse("\"hi\\n\"").unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parses_nested() {
        let j = parse(r#"{"a":[1,{"b":null},"x"],"c":true}"#).unwrap();
        assert_eq!(j.at(&["a"]).unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(j.get("c").unwrap().as_bool(), Some(true));
    }

    #[test]
    fn surrogate_pairs() {
        let j = parse(r#""😀""#).unwrap();
        assert_eq!(j.as_str(), Some("😀"));
    }

    #[test]
    fn rejects_garbage() {
        for bad in ["{", "[1,", "tru", "{\"a\" 1}", "1 2", "\"\\q\""] {
            assert!(parse(bad).is_err(), "should reject {bad:?}");
        }
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let src = r#"{"m":{"x":[1,2.5,-3],"s":"a\"b","n":null},"e":[],"o":{}}"#;
        let j = parse(src).unwrap();
        assert_eq!(parse(&j.to_string_compact()).unwrap(), j);
        assert_eq!(parse(&j.to_string_pretty()).unwrap(), j);
    }

    #[test]
    fn builder_and_path() {
        let j = Json::obj()
            .set("a", 1.0)
            .set("b", Json::obj().set("c", "deep"));
        assert_eq!(j.at(&["b", "c"]).unwrap().as_str(), Some("deep"));
        assert_eq!(j.keys(), vec!["a", "b"]);
    }

    #[test]
    fn integers_serialize_without_fraction() {
        assert_eq!(Json::Num(42.0).to_string_compact(), "42");
        assert_eq!(Json::Num(0.5).to_string_compact(), "0.5");
    }

    #[test]
    fn fuzz_never_panics_and_valid_docs_roundtrip() {
        use crate::util::prop::forall;
        use crate::util::rng::Rng;
        // random byte soup: parse must return (Ok|Err), never panic
        forall(
            "json parser total on garbage",
            300,
            |r: &mut Rng| {
                let n = r.below(64);
                (0..n).map(|_| (r.below(96) as u8 + 32) as char).collect::<String>()
            },
            |s| {
                let _ = parse(s);
                Ok(())
            },
        );
        // random *valid* documents round-trip exactly
        fn gen_value(r: &mut Rng, depth: usize) -> Json {
            match if depth == 0 { r.below(4) } else { r.below(6) } {
                0 => Json::Null,
                1 => Json::Bool(r.f64() < 0.5),
                2 => Json::Num((r.normal() * 100.0 * 8.0).round() / 8.0),
                3 => Json::Str((0..r.below(8))
                    .map(|_| char::from(b'a' + r.below(26) as u8))
                    .collect()),
                4 => Json::Arr((0..r.below(4)).map(|_| gen_value(r, depth - 1)).collect()),
                _ => Json::Obj((0..r.below(4))
                    .map(|i| (format!("k{i}"), gen_value(r, depth - 1)))
                    .collect()),
            }
        }
        forall(
            "json roundtrip",
            200,
            |r: &mut Rng| gen_value(r, 3),
            |v| {
                let back = parse(&v.to_string_pretty())
                    .map_err(|e| format!("parse-back failed: {e}"))?;
                if &back == v {
                    Ok(())
                } else {
                    Err(format!("roundtrip mismatch: {back:?}"))
                }
            },
        );
    }
}
