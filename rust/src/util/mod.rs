//! Offline substrates: the pieces a networked build would pull from
//! crates.io, implemented in-repo (DESIGN.md §2).

pub mod cli;
pub mod json;
pub mod pool;
pub mod prop;
pub mod rng;
pub mod timer;
