//! Thread-pool + bounded-queue pipeline runtime (tokio stand-in).
//!
//! Two primitives:
//! * [`ThreadPool`] — fixed worker pool executing boxed jobs; `scope`-free,
//!   jobs must be `'static`. Used for batch fan-out in benches and the PPO
//!   rollout workers.
//! * pipeline stages connected by bounded [`Sender`]/[`Receiver`]
//!   channels with backpressure — the coordinator's request path
//!   (router → batcher → agent → link → edge) runs on this.

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

// ---------------------------------------------------------------------------
// bounded MPMC channel (Mutex + Condvar)
// ---------------------------------------------------------------------------

struct ChanInner<T> {
    queue: Mutex<ChanState<T>>,
    not_full: Condvar,
    not_empty: Condvar,
}

struct ChanState<T> {
    buf: VecDeque<T>,
    cap: usize,
    closed: bool,
    senders: usize,
}

/// Sending half; cloneable. The channel closes when the last sender drops.
pub struct Sender<T>(Arc<ChanInner<T>>);

/// Receiving half; cloneable (MPMC).
pub struct Receiver<T>(Arc<ChanInner<T>>);

pub fn bounded<T>(cap: usize) -> (Sender<T>, Receiver<T>) {
    let inner = Arc::new(ChanInner {
        queue: Mutex::new(ChanState {
            buf: VecDeque::new(),
            cap: cap.max(1),
            closed: false,
            senders: 1,
        }),
        not_full: Condvar::new(),
        not_empty: Condvar::new(),
    });
    (Sender(inner.clone()), Receiver(inner))
}

impl<T> Clone for Sender<T> {
    fn clone(&self) -> Self {
        self.0.queue.lock().unwrap().senders += 1;
        Sender(self.0.clone())
    }
}

impl<T> Drop for Sender<T> {
    fn drop(&mut self) {
        let mut st = self.0.queue.lock().unwrap();
        st.senders -= 1;
        if st.senders == 0 {
            st.closed = true;
            drop(st);
            self.0.not_empty.notify_all();
        }
    }
}

impl<T> Clone for Receiver<T> {
    fn clone(&self) -> Self {
        Receiver(self.0.clone())
    }
}

#[derive(Debug, PartialEq, Eq)]
pub struct Closed;

impl<T> Sender<T> {
    /// Blocking send with backpressure; fails only if all receivers dropped
    /// the channel via close().
    pub fn send(&self, item: T) -> Result<(), Closed> {
        let mut st = self.0.queue.lock().unwrap();
        while st.buf.len() >= st.cap && !st.closed {
            st = self.0.not_full.wait(st).unwrap();
        }
        if st.closed {
            return Err(Closed);
        }
        st.buf.push_back(item);
        drop(st);
        self.0.not_empty.notify_one();
        Ok(())
    }
}

impl<T> Receiver<T> {
    /// Blocking receive; `None` once the channel is closed **and** drained.
    pub fn recv(&self) -> Option<T> {
        let mut st = self.0.queue.lock().unwrap();
        loop {
            if let Some(item) = st.buf.pop_front() {
                drop(st);
                self.0.not_full.notify_one();
                return Some(item);
            }
            if st.closed {
                return None;
            }
            st = self.0.not_empty.wait(st).unwrap();
        }
    }

    /// Non-blocking receive.
    pub fn try_recv(&self) -> Option<T> {
        let mut st = self.0.queue.lock().unwrap();
        let item = st.buf.pop_front();
        if item.is_some() {
            drop(st);
            self.0.not_full.notify_one();
        }
        item
    }

    /// Drain everything currently queued without blocking.
    pub fn drain(&self) -> Vec<T> {
        let mut st = self.0.queue.lock().unwrap();
        let out: Vec<T> = st.buf.drain(..).collect();
        if !out.is_empty() {
            drop(st);
            self.0.not_full.notify_all();
        }
        out
    }

    /// Hard-close from the receiver side (consumers shutting down).
    pub fn close(&self) {
        let mut st = self.0.queue.lock().unwrap();
        st.closed = true;
        drop(st);
        self.0.not_empty.notify_all();
        self.0.not_full.notify_all();
    }

    pub fn len(&self) -> usize {
        self.0.queue.lock().unwrap().buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

// ---------------------------------------------------------------------------
// thread pool
// ---------------------------------------------------------------------------

pub struct ThreadPool {
    tx: Option<Sender<Job>>,
    workers: Vec<JoinHandle<()>>,
    pending: Arc<(Mutex<usize>, Condvar)>,
}

impl ThreadPool {
    pub fn new(n: usize) -> ThreadPool {
        let (tx, rx) = bounded::<Job>(4 * n.max(1));
        let pending = Arc::new((Mutex::new(0usize), Condvar::new()));
        let workers = (0..n.max(1))
            .map(|i| {
                let rx = rx.clone();
                let pending = pending.clone();
                std::thread::Builder::new()
                    .name(format!("qaci-worker-{i}"))
                    .spawn(move || {
                        while let Some(job) = rx.recv() {
                            job();
                            let (lock, cv) = &*pending;
                            let mut p = lock.lock().unwrap();
                            *p -= 1;
                            if *p == 0 {
                                cv.notify_all();
                            }
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { tx: Some(tx), workers, pending }
    }

    pub fn execute(&self, job: impl FnOnce() + Send + 'static) {
        *self.pending.0.lock().unwrap() += 1;
        self.tx
            .as_ref()
            .expect("pool alive")
            .send(Box::new(job))
            .expect("pool accepting jobs");
    }

    /// Block until every submitted job has finished.
    pub fn wait_idle(&self) {
        let (lock, cv) = &*self.pending;
        let mut p = lock.lock().unwrap();
        while *p > 0 {
            p = cv.wait(p).unwrap();
        }
    }

    /// Map a slice in parallel preserving order.
    pub fn map<T, R>(&self, items: Vec<T>, f: impl Fn(T) -> R + Send + Sync + 'static) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
    {
        let n = items.len();
        let out = Arc::new(Mutex::new((0..n).map(|_| None).collect::<Vec<Option<R>>>()));
        let f = Arc::new(f);
        for (i, item) in items.into_iter().enumerate() {
            let out = out.clone();
            let f = f.clone();
            self.execute(move || {
                let r = f(item);
                out.lock().unwrap()[i] = Some(r);
            });
        }
        self.wait_idle();
        Arc::try_unwrap(out)
            .ok()
            .expect("all workers done")
            .into_inner()
            .unwrap()
            .into_iter()
            .map(|r| r.expect("slot filled"))
            .collect()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.tx.take(); // closes the channel; workers drain and exit
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// How many workers to use by default.
pub fn default_parallelism() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};

    #[test]
    fn channel_fifo_and_close() {
        let (tx, rx) = bounded::<u32>(8);
        for i in 0..5 {
            tx.send(i).unwrap();
        }
        drop(tx);
        let got: Vec<u32> = std::iter::from_fn(|| rx.recv()).collect();
        assert_eq!(got, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn channel_backpressure_blocks_then_resumes() {
        let (tx, rx) = bounded::<u32>(1);
        tx.send(1).unwrap();
        let t = std::thread::spawn(move || tx.send(2).map(|_| 2).unwrap_or(0));
        std::thread::sleep(std::time::Duration::from_millis(20));
        assert_eq!(rx.recv(), Some(1)); // unblocks the sender
        assert_eq!(t.join().unwrap(), 2);
        assert_eq!(rx.recv(), Some(2));
    }

    #[test]
    fn receiver_close_unblocks_sender() {
        let (tx, rx) = bounded::<u32>(1);
        tx.send(1).unwrap();
        let t = std::thread::spawn(move || tx.send(2));
        std::thread::sleep(std::time::Duration::from_millis(20));
        rx.close();
        assert_eq!(t.join().unwrap(), Err(Closed));
    }

    #[test]
    fn pool_runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = counter.clone();
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn pool_map_preserves_order() {
        let pool = ThreadPool::new(8);
        let out = pool.map((0..64).collect::<Vec<u64>>(), |x| x * x);
        assert_eq!(out, (0..64).map(|x| x * x).collect::<Vec<u64>>());
    }

    #[test]
    fn no_job_lost_under_contention() {
        // conservation invariant used by the batcher tests too
        let (tx, rx) = bounded::<u64>(3);
        let seen = Arc::new(AtomicUsize::new(0));
        let consumers: Vec<_> = (0..4)
            .map(|_| {
                let rx = rx.clone();
                let seen = seen.clone();
                std::thread::spawn(move || {
                    while rx.recv().is_some() {
                        seen.fetch_add(1, Ordering::SeqCst);
                    }
                })
            })
            .collect();
        let producers: Vec<_> = (0..4)
            .map(|p| {
                let tx = tx.clone();
                std::thread::spawn(move || {
                    for i in 0..250 {
                        tx.send(p * 1000 + i).unwrap();
                    }
                })
            })
            .collect();
        for p in producers {
            p.join().unwrap();
        }
        drop(tx);
        for c in consumers {
            c.join().unwrap();
        }
        assert_eq!(seen.load(Ordering::SeqCst), 1000);
    }
}
