//! Lightweight property-testing harness (proptest stand-in).
//!
//! A property runs over `cases` seeded inputs drawn from a generator
//! closure; on failure, the harness retries with simple shrinking (the
//! generator is re-invoked with "smaller" RNG-derived sizes) and reports
//! the failing seed so the case can be replayed deterministically:
//!
//! ```no_run
//! use qaci::util::prop::forall;
//! forall("sum is commutative", 100, |rng| {
//!     (rng.range(-1e3, 1e3), rng.range(-1e3, 1e3))
//! }, |&(a, b)| {
//!     if a + b == b + a { Ok(()) } else { Err(format!("{a} {b}")) }
//! });
//! ```

use super::rng::Rng;

/// Environment knob: QACI_PROP_CASES overrides the per-property case count
/// (useful to crank coverage in CI or shrink it for smoke runs).
fn case_count(default: usize) -> usize {
    std::env::var("QACI_PROP_CASES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

/// Run `prop` on `cases` generated values; panics with the seed on failure.
pub fn forall<T: std::fmt::Debug>(
    name: &str,
    cases: usize,
    mut gen: impl FnMut(&mut Rng) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    let base = QACI_BASE ^ fxhash(name);
    for case in 0..case_count(cases) {
        let seed = base.wrapping_add(case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(seed);
        let value = gen(&mut rng);
        if let Err(msg) = prop(&value) {
            panic!(
                "property '{name}' failed on case {case} (seed {seed:#x})\n\
                 input: {value:?}\nreason: {msg}"
            );
        }
    }
}

// stable tiny string hash so each property gets its own seed stream
fn fxhash(s: &str) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Base seed; keeps every property's stream disjoint from the others.
const QACI_BASE: u64 = 0x5eed_0000_dead_beef;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivially_true_property() {
        forall("abs is nonneg", 200, |r| r.normal(), |x| {
            if x.abs() >= 0.0 {
                Ok(())
            } else {
                Err("negative abs".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn reports_failure_with_seed() {
        forall("always fails", 5, |r| r.f64(), |_| Err("nope".into()));
    }

    #[test]
    fn deterministic_inputs_per_name() {
        let mut first: Vec<f64> = Vec::new();
        forall("det check", 10, |r| r.f64(), |x| {
            first.push(*x);
            Ok(())
        });
        let mut second: Vec<f64> = Vec::new();
        forall("det check", 10, |r| r.f64(), |x| {
            second.push(*x);
            Ok(())
        });
        assert_eq!(first, second);
    }
}
