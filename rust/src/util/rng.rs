//! Deterministic RNG: xoshiro256** seeded via splitmix64, plus the
//! distribution samplers the simulators need (uniform, normal, exponential,
//! Laplace). No external crates; every experiment is reproducible from a
//! single u64 seed.

#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Rng {
        // splitmix64 expansion of the seed into the xoshiro state
        let mut x = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = move || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    /// Derive an independent stream (for per-thread / per-component rngs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire-style rejection-free for our (non-crypto) purposes
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box–Muller (polar form).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u = 2.0 * self.f64() - 1.0;
            let v = 2.0 * self.f64() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                return u * (-2.0 * s.ln() / s).sqrt();
            }
        }
    }

    /// Exponential with rate lambda (mean 1/lambda) — the paper's weight
    /// magnitude model (eq. 3).
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        debug_assert!(lambda > 0.0);
        -(1.0 - self.f64()).ln() / lambda
    }

    /// Zero-mean Laplace with E|Z| = d — the optimal test-channel noise of
    /// Lemma 4.2.
    pub fn laplace(&mut self, d: f64) -> f64 {
        let u = self.f64() - 0.5;
        -d * u.signum() * (1.0 - 2.0 * u.abs()).ln()
    }

    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            xs.swap(i, self.below(i + 1));
        }
    }

    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(8);
        assert_ne!(Rng::new(7).next_u64(), c.next_u64());
    }

    #[test]
    fn uniform_range_and_moments() {
        let mut r = Rng::new(1);
        let n = 200_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(2);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn exponential_mean_is_inverse_lambda() {
        let mut r = Rng::new(3);
        let lambda = 4.0;
        let n = 200_000;
        let mean: f64 = (0..n).map(|_| r.exponential(lambda)).sum::<f64>() / n as f64;
        assert!((mean - 0.25).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn laplace_mean_abs_is_d() {
        let mut r = Rng::new(4);
        let d = 0.3;
        let n = 200_000;
        let mean_abs: f64 = (0..n).map(|_| r.laplace(d).abs()).sum::<f64>() / n as f64;
        assert!((mean_abs - d).abs() < 0.01, "E|Z| {mean_abs}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_diverge() {
        let mut r = Rng::new(6);
        let mut a = r.fork(1);
        let mut b = r.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
