//! Wall-clock measurement + summary statistics for the bench harness and
//! the coordinator's telemetry.

use std::time::Instant;

/// Simple stopwatch.
pub struct Stopwatch(Instant);

impl Stopwatch {
    pub fn start() -> Stopwatch {
        Stopwatch(Instant::now())
    }

    pub fn elapsed_s(&self) -> f64 {
        self.0.elapsed().as_secs_f64()
    }

    pub fn elapsed_us(&self) -> f64 {
        self.0.elapsed().as_secs_f64() * 1e6
    }
}

/// Streaming-ish sample collection with percentile summaries.
#[derive(Debug, Clone, Default)]
pub struct Samples {
    xs: Vec<f64>,
}

impl Samples {
    pub fn new() -> Samples {
        Samples::default()
    }

    pub fn push(&mut self, x: f64) {
        self.xs.push(x);
    }

    pub fn len(&self) -> usize {
        self.xs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.xs.is_empty()
    }

    /// Raw samples (fleet rollups merge per-agent collections).
    pub fn values(&self) -> &[f64] {
        &self.xs
    }

    /// Concatenate another collection's samples into this one — the
    /// per-agent → fleet rollup every report layer shares.
    pub fn merge(&mut self, other: &Samples) {
        self.xs.extend_from_slice(&other.xs);
    }

    pub fn mean(&self) -> f64 {
        if self.xs.is_empty() {
            return f64::NAN;
        }
        self.xs.iter().sum::<f64>() / self.xs.len() as f64
    }

    pub fn min(&self) -> f64 {
        self.xs.iter().copied().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.xs.iter().copied().fold(f64::NEG_INFINITY, f64::max)
    }

    pub fn std(&self) -> f64 {
        let m = self.mean();
        if self.xs.len() < 2 {
            return 0.0;
        }
        (self.xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>()
            / (self.xs.len() - 1) as f64)
            .sqrt()
    }

    /// Linear-interpolated percentile, p in [0, 100] (delegates to the
    /// crate's one shared implementation in [`crate::obs::stats`]).
    pub fn percentile(&self, p: f64) -> f64 {
        crate::obs::stats::percentile(&self.xs, p)
    }

    pub fn p50(&self) -> f64 {
        self.percentile(50.0)
    }

    pub fn p95(&self) -> f64 {
        self.percentile(95.0)
    }

    pub fn p99(&self) -> f64 {
        self.percentile(99.0)
    }

    pub fn summary(&self, unit: &str) -> String {
        format!(
            "n={} mean={:.3}{u} p50={:.3}{u} p95={:.3}{u} min={:.3}{u} max={:.3}{u}",
            self.len(),
            self.mean(),
            self.p50(),
            self.p95(),
            self.min(),
            self.max(),
            u = unit
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_of_known_sequence() {
        let mut s = Samples::new();
        for i in 1..=100 {
            s.push(i as f64);
        }
        assert!((s.p50() - 50.5).abs() < 1e-9);
        assert!((s.percentile(0.0) - 1.0).abs() < 1e-9);
        assert!((s.percentile(100.0) - 100.0).abs() < 1e-9);
        assert!((s.mean() - 50.5).abs() < 1e-9);
    }

    #[test]
    fn merge_concatenates_samples() {
        let mut a = Samples::new();
        a.push(1.0);
        a.push(2.0);
        let mut b = Samples::new();
        b.push(10.0);
        a.merge(&b);
        assert_eq!(a.values(), &[1.0, 2.0, 10.0]);
        a.merge(&Samples::new());
        assert_eq!(a.len(), 3);
    }

    #[test]
    fn std_of_constant_is_zero() {
        let mut s = Samples::new();
        for _ in 0..10 {
            s.push(3.0);
        }
        assert_eq!(s.std(), 0.0);
    }

    #[test]
    fn stopwatch_monotone() {
        let sw = Stopwatch::start();
        let a = sw.elapsed_s();
        let b = sw.elapsed_s();
        assert!(b >= a && a >= 0.0);
    }
}
