//! Golden-value regression tests for the figure-generating theory math:
//! the §IV rate–distortion bounds ([`qaci::theory::rate_distortion`]) and
//! the §III output-distortion propagation ([`qaci::theory::distortion`]).
//!
//! The property tests in-module assert *shapes* (monotonicity, bound
//! ordering, inversion); these tests pin *values* on a fixed bit-width
//! grid so the numbers behind every figure cannot silently drift. The
//! constants were computed independently (IEEE-754 f64, same operation
//! order as the Rust expressions) and agree to well under 1e-12 relative.

use qaci::theory::distortion::{fc_forward, output_distortion_bound, surrogate_l1, LayerMatrix};
use qaci::theory::rate_distortion as rd;

fn assert_close(got: f64, want: f64, what: &str) {
    let tol = 1e-12 * want.abs().max(1e-300);
    assert!(
        (got - want).abs() <= tol,
        "{what}: got {got:.17e}, pinned {want:.17e}"
    );
}

/// D^L(b̂−1) on the full achievable bit-width grid at the canonical
/// λ = 15 (the fitted magnitude parameter every preset fleet uses).
#[test]
fn golden_d_lower_grid_lambda_15() {
    #[rustfmt::skip]
    let pinned = [
        3.33333333333333329e-02, 1.66666666666666664e-02, 8.33333333333333322e-03,
        4.16666666666666661e-03, 2.08333333333333330e-03, 1.04166666666666665e-03,
        5.20833333333333326e-04, 2.60416666666666663e-04, 1.30208333333333332e-04,
        6.51041666666666658e-05, 3.25520833333333329e-05, 1.62760416666666664e-05,
        8.13802083333333322e-06, 4.06901041666666661e-06, 2.03450520833333331e-06,
        1.01725260416666665e-06,
    ];
    for (b, want) in (1..=16u32).zip(pinned) {
        assert_close(rd::d_lower(b as f64 - 1.0, 15.0), want, &format!("D^L(b̂={b})"));
    }
}

/// D^U(b̂−1) on the same grid at λ = 15.
#[test]
fn golden_d_upper_grid_lambda_15() {
    #[rustfmt::skip]
    let pinned = [
        6.66666666666666657e-02, 4.12022659166596597e-02, 1.75841743883982174e-02,
        8.45221136853391286e-03, 4.18209559140918490e-03, 2.08530987527011380e-03,
        1.04191718683764502e-03, 5.20864879856082963e-04, 2.60420624968101444e-04,
        1.30208829074240912e-04, 6.51042286943829624e-05, 3.25520910905652286e-05,
        1.62760426365575008e-05, 8.13802095458449026e-06, 4.06901043182491158e-06,
        2.03450521022811382e-06,
    ];
    for (b, want) in (1..=16u32).zip(pinned) {
        assert_close(rd::d_upper(b as f64 - 1.0, 15.0), want, &format!("D^U(b̂={b})"));
    }
}

/// Spot pins at the sweep extremes λ = 4 and λ = 50 (the benches sweep
/// λ across models), plus the (P1) objective values the allocator
/// actually minimizes.
#[test]
fn golden_spot_values_other_lambdas_and_gap() {
    assert_close(rd::d_lower(0.0, 4.0), 1.25000000000000000e-01, "D^L(0) λ=4");
    assert_close(rd::d_upper(1.0, 4.0), 1.54508497187473726e-01, "D^U(1) λ=4");
    assert_close(rd::d_upper(7.0, 4.0), 1.95324329946031106e-03, "D^U(7) λ=4");
    assert_close(rd::d_lower(15.0, 50.0), 3.05175781250000006e-07, "D^L(15) λ=50");
    assert_close(rd::d_upper(15.0, 50.0), 6.10351563068434189e-07, "D^U(15) λ=50");
    assert_close(rd::bound_gap(1.0, 15.0), 3.33333333333333329e-02, "gap(1)");
    assert_close(rd::bound_gap(2.0, 15.0), 2.45355992499929933e-02, "gap(2)");
    assert_close(rd::bound_gap(4.0, 15.0), 4.28554470186724625e-03, "gap(4)");
    assert_close(rd::bound_gap(8.0, 15.0), 2.60448213189416300e-04, "gap(8)");
    assert_close(rd::bound_gap(16.0, 15.0), 1.01725260606144717e-06, "gap(16)");
}

/// Structural invariants re-checked on the pinned grid: the lower bound
/// sits below the upper everywhere and both fall monotonically in b̂ —
/// if a refactor bends either shape, the pins above catch the values
/// and this catches the geometry.
#[test]
fn golden_grid_is_ordered_and_monotone() {
    for lambda in [4.0, 15.0, 50.0] {
        let mut prev_lo = f64::INFINITY;
        let mut prev_hi = f64::INFINITY;
        for b in 1..=16u32 {
            let rate = b as f64 - 1.0;
            let (lo, hi) = (rd::d_lower(rate, lambda), rd::d_upper(rate, lambda));
            assert!(lo <= hi, "λ={lambda} b̂={b}: D^L {lo} > D^U {hi}");
            assert!(lo < prev_lo, "λ={lambda} b̂={b}: D^L not strictly decreasing");
            assert!(hi <= prev_hi, "λ={lambda} b̂={b}: D^U not decreasing");
            prev_lo = lo;
            prev_hi = hi;
        }
    }
}

/// Fixed two-layer net with dyadic (exactly representable) weights and
/// a dyadic quantization perturbation: every Prop. 3.1 quantity is an
/// exact binary fraction, pinned here end to end.
#[test]
fn golden_output_distortion_fixed_net() {
    let w1 = LayerMatrix::new(2, 2, vec![0.5, -0.25, 0.75, 1.0]);
    let w2 = LayerMatrix::new(2, 2, vec![1.0, 0.5, -0.5, 0.25]);
    let q1 = LayerMatrix::new(2, 2, vec![0.625, -0.25, 0.75, 0.9375]);
    let q2 = LayerMatrix::new(2, 2, vec![1.0, 0.53125, -0.4375, 0.25]);

    // induced-L1 operator norms (max absolute column sum)
    assert_close(w1.induced_l1(), 1.25, "‖W1‖");
    assert_close(w2.induced_l1(), 1.5, "‖W2‖");
    assert_close(w1.entrywise_l1(), 2.5, "‖W1‖_entrywise");

    // per-layer quantization errors in both norms
    assert_close(w1.sub_l1_induced(&q1), 0.125, "τ1");
    assert_close(w2.sub_l1_induced(&q2), 0.0625, "τ2");
    assert_close(
        surrogate_l1(&[w1.clone(), w2.clone()], &[q1.clone(), q2.clone()]),
        0.28125,
        "surrogate eq. 15",
    );

    // Prop. 3.1: A_1 = (‖W2‖ + τ2), A_2 = ‖W1‖ → bound Σ A_l τ_l
    assert_close(
        output_distortion_bound(&[w1.clone(), w2.clone()], &[q1.clone(), q2.clone()]),
        0.2734375,
        "Prop. 3.1 bound",
    );

    // forward passes at the normalized input x = (0.75, 0.25)
    let x = [0.75, 0.25];
    let y = fc_forward(&[w1.clone(), w2.clone()], &x);
    let yq = fc_forward(&[q1, q2], &x);
    assert_close(y[0], 0.71875, "y[0]");
    assert_close(y[1], 0.046875, "y[1]");
    assert_close(yq[0], 0.82958984375, "ŷ[0]");
    assert_close(yq[1], 0.021484375, "ŷ[1]");
    let true_dist: f64 = y.iter().zip(&yq).map(|(a, b)| (a - b).abs()).sum();
    assert_close(true_dist, 0.13623046875, "‖f(x,W)−f(x,Ŵ)‖₁");
    // and the pinned bound dominates the pinned truth, as Prop. 3.1 demands
    assert!(true_dist <= 0.2734375);
}
