//! Observability integration: the `qaci bench-log` CLI surface
//! (ingest/query/diff through a real subprocess), the `--metrics-out`
//! snapshot of a full churn+events fleet run, and the committed CI
//! ordering baseline (`ci/benchlog-baseline.jsonl`) — including that its
//! Python-generated digests verify through the Rust reader.

use qaci::obs::benchlog::{self, BenchLog, DiffOptions};
use qaci::util::json::{self, Json};
use std::path::PathBuf;

fn tmpdir(tag: &str) -> PathBuf {
    let dir =
        std::env::temp_dir().join(format!("qaci-benchlog-it-{tag}-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Spawn the real binary; returns (stdout, stderr, success).
fn qaci(args: &[&str]) -> (String, String, bool) {
    let out = std::process::Command::new(env!("CARGO_BIN_EXE_qaci"))
        .args(args)
        .output()
        .expect("qaci binary runs");
    (
        String::from_utf8_lossy(&out.stdout).into_owned(),
        String::from_utf8_lossy(&out.stderr).into_owned(),
        out.status.success(),
    )
}

/// A minimal bench artifact shaped like the fleet_churn emission.
fn storm_artifact(online_p99: f64) -> Json {
    let row = |policy: &str, cost: f64, p99: f64| {
        Json::obj()
            .set("scenario", "burst-storm")
            .set("policy", policy)
            .set("cost", cost)
            .set("p99_s", p99)
    };
    Json::obj().set("bench", "fleet_churn").set("version", 1.0).set(
        "results",
        Json::Arr(vec![
            row("online-proposed", 1.0, online_p99),
            row("static-proposed", 4.0, 220.0),
        ]),
    )
}

/// `qaci fleet --churn --events --metrics-out` writes a schema-versioned
/// snapshot whose solver counters and queue histograms are populated by
/// the run — the acceptance criterion for the instrumentation layer.
#[test]
fn cli_metrics_out_emits_populated_snapshot() {
    let path = tmpdir("metrics").join("metrics.json");
    let _ = std::fs::remove_file(&path);
    let (stdout, stderr, ok) = qaci(&[
        "fleet", "--churn", "--events", "--queue", "fifo", "--tiers", "orin,xavier,phone",
        "--horizon", "240", "--seed", "0", "--metrics-out", path.to_str().unwrap(),
    ]);
    assert!(ok, "fleet run failed:\nstdout:\n{stdout}\nstderr:\n{stderr}");
    assert!(stdout.contains("wrote metrics snapshot"), "{stdout}");
    let j = json::parse_file(&path).expect("snapshot parses");
    assert_eq!(j.get("schema").and_then(Json::as_str), Some("qaci.metrics"));
    assert_eq!(j.get("version").and_then(Json::as_usize), Some(1));
    let counter = |name: &str| j.at(&["counters", name]).and_then(Json::as_f64).unwrap_or(0.0);
    assert!(counter("solver.bisection.calls") > 0.0, "allocator never ran:\n{j}");
    assert!(
        counter("solver.warm_start.hit") + counter("solver.warm_start.miss") > 0.0,
        "fingerprint gate never evaluated:\n{j}"
    );
    assert!(counter("events.arrivals") > 0.0, "event replay contributed nothing:\n{j}");
    for hist in ["queue.depth", "queue.wait_s", "events.queue_depth", "span.events.run.s"] {
        let n = j.at(&["histograms", hist, "n"]).and_then(Json::as_usize).unwrap_or(0);
        assert!(n > 0, "histogram {hist} empty or missing:\n{j}");
    }
}

/// End-to-end store lifecycle through the CLI: two identical runs diff
/// clean; an injected p99 regression trips both the value and the
/// ordering check and — with --fail-on-regression — a nonzero exit.
#[test]
fn cli_bench_log_ingest_query_diff_lifecycle() {
    let dir = tmpdir("lifecycle");
    let index = dir.join("index.jsonl");
    let _ = std::fs::remove_file(&index);
    let good = dir.join("good.json");
    let bad = dir.join("bad.json");
    std::fs::write(&good, storm_artifact(19.7).to_string_pretty()).unwrap();
    std::fs::write(&bad, storm_artifact(500.0).to_string_pretty()).unwrap();
    let idx = index.to_str().unwrap();

    // two identical runs: ingest assigns sequential seqs, diff is clean
    for seq in 0..2 {
        let (stdout, stderr, ok) =
            qaci(&["bench-log", "ingest", good.to_str().unwrap(), "--index", idx]);
        assert!(ok, "ingest failed:\n{stderr}");
        assert!(stdout.contains(&format!("seq {seq}")), "{stdout}");
        assert!(stdout.contains("fnv1a:"), "digest missing from receipt: {stdout}");
    }
    let (stdout, _, ok) = qaci(&["bench-log", "diff", "--index", idx, "--fail-on-regression"]);
    assert!(ok, "identical runs must diff clean:\n{stdout}");
    assert!(stdout.contains("clean"), "{stdout}");

    // inject the regression: latest-vs-previous diff now finds both a
    // value regression and the p99 ordering inversion
    let (_, stderr, ok) = qaci(&["bench-log", "ingest", bad.to_str().unwrap(), "--index", idx]);
    assert!(ok, "{stderr}");
    let (stdout, _, ok) = qaci(&["bench-log", "diff", "--index", idx, "--fail-on-regression"]);
    assert!(!ok, "regression must exit nonzero:\n{stdout}");
    assert!(stdout.contains("[regression]"), "{stdout}");
    assert!(stdout.contains("[ordering]"), "{stdout}");
    // CI mode ignores absolute values but still catches the inversion
    let (stdout, _, ok) = qaci(&[
        "bench-log", "diff", "--index", idx, "--orderings-only", "--fail-on-regression",
    ]);
    assert!(!ok, "ordering inversion must fail CI mode:\n{stdout}");
    assert!(stdout.contains("[ordering]") && !stdout.contains("[regression]"), "{stdout}");
    // without --fail-on-regression the findings report but exit 0
    let (stdout, _, ok) = qaci(&["bench-log", "diff", "--index", idx]);
    assert!(ok, "report-only diff must not fail:\n{stdout}");
    assert!(stdout.contains("finding(s)"), "{stdout}");

    // query: the regressed value is visible in the trajectory
    let (stdout, _, ok) = qaci(&[
        "bench-log", "query", "--index", idx, "--scenario", "burst-storm", "--policy",
        "online-proposed", "--field", "p99_s",
    ]);
    assert!(ok, "{stdout}");
    assert!(stdout.contains("500"), "regressed p99 missing: {stdout}");
    assert!(stdout.contains("3 row(s)"), "{stdout}");
    let (stdout, _, _) = qaci(&[
        "bench-log", "query", "--index", idx, "--field", "p99_s", "--policy",
        "online-proposed", "--last", "1",
    ]);
    assert!(stdout.contains("1 row(s)"), "--last must truncate: {stdout}");
}

/// A corrupted index entry (payload byte flip after ingest) is rejected
/// by the digest check on every subsequent read path.
#[test]
fn cli_bench_log_rejects_corrupted_index() {
    let dir = tmpdir("corrupt");
    let index = dir.join("index.jsonl");
    let _ = std::fs::remove_file(&index);
    let artifact = dir.join("run.json");
    std::fs::write(&artifact, storm_artifact(19.7).to_string_pretty()).unwrap();
    let idx = index.to_str().unwrap();
    let (_, stderr, ok) =
        qaci(&["bench-log", "ingest", artifact.to_str().unwrap(), "--index", idx]);
    assert!(ok, "{stderr}");
    // flip one payload byte, keeping the line valid JSON
    let line = std::fs::read_to_string(&index).unwrap();
    let tampered = line.replace("\"cost\":4", "\"cost\":8");
    assert_ne!(tampered, line, "tamper must apply");
    std::fs::write(&index, tampered).unwrap();
    for sub in [vec!["query"], vec!["diff"], vec!["ingest", artifact.to_str().unwrap()]] {
        let mut args = vec!["bench-log"];
        args.extend(sub.iter().copied());
        args.extend(["--index", idx]);
        let (stdout, stderr, ok) = qaci(&args);
        assert!(!ok, "{sub:?} must reject a corrupted index:\n{stdout}");
        assert!(stderr.contains("digest mismatch"), "{sub:?}: {stderr}");
    }
}

/// Substitute the `results` array of a bench payload, preserving every
/// other key in place (Json::set appends, so a rebuild is needed).
fn with_results(payload: &Json, rows: Vec<Json>) -> Json {
    let Json::Obj(kv) = payload else { panic!("payload must be an object") };
    Json::Obj(
        kv.iter()
            .map(|(k, v)| {
                if k == "results" {
                    (k.clone(), Json::Arr(rows.clone()))
                } else {
                    (k.clone(), v.clone())
                }
            })
            .collect(),
    )
}

/// Rewrite every numeric field of every result row through `f(key, x)`.
fn rescale(payload: &Json, f: &dyn Fn(&str, f64) -> f64) -> Json {
    let results = payload.get("results").and_then(Json::as_arr).expect("results array");
    let rows: Vec<Json> = results
        .iter()
        .map(|r| {
            let Json::Obj(kv) = r else { panic!("row must be an object") };
            Json::Obj(
                kv.iter()
                    .map(|(k, v)| match v.as_f64() {
                        Some(x) => (k.clone(), Json::Num(f(k, x))),
                        None => (k.clone(), v.clone()),
                    })
                    .collect(),
            )
        })
        .collect();
    with_results(payload, rows)
}

/// The committed CI baseline is readable by this build (which also
/// verifies its Python-generated digests match the Rust FNV-1a over the
/// canonical payload bytes), and its orderings gate exactly as designed:
/// any order-preserving rescale of the tracked fields diffs clean, an
/// inverted burst-storm tail does not.
#[test]
fn committed_ci_baseline_verifies_and_gates_orderings() {
    let base_path = concat!(env!("CARGO_MANIFEST_DIR"), "/ci/benchlog-baseline.jsonl");
    let baseline = BenchLog::open(base_path);
    let entries = baseline.entries().expect("baseline digests verify");
    let benches: Vec<&str> = entries.iter().map(|e| e.bench.as_str()).collect();
    assert_eq!(
        benches,
        ["fleet_churn", "fleet_scale", "fleet_placement", "fleet_daemon", "fleet_quant"]
    );

    // an order-preserving transform of every tracked field (a "healthy
    // run on a different machine"): strictly monotone, so strict
    // baseline orderings survive and nothing regresses
    let dir = tmpdir("baseline");
    let healthy = BenchLog::open(dir.join("healthy.jsonl"));
    let _ = std::fs::remove_file(healthy.path());
    for e in &entries {
        let run = rescale(&e.payload, &|_, x| 0.125 * x);
        healthy.ingest(&e.bench, "bench", &run).unwrap();
    }
    let ci_opts = DiffOptions { orderings_only: true, ..DiffOptions::default() };
    let findings = benchlog::diff(&healthy, &baseline, &ci_opts).unwrap();
    assert!(findings.is_empty(), "healthy rescale must gate clean: {findings:?}");
    // even the full value check passes — everything improved
    let findings = benchlog::diff(&healthy, &baseline, &DiffOptions::default()).unwrap();
    assert!(findings.is_empty(), "improvement flagged as regression: {findings:?}");

    // invert the burst-storm tail: online p99 above the statics
    let broken = BenchLog::open(dir.join("broken.jsonl"));
    let _ = std::fs::remove_file(broken.path());
    for e in &entries {
        // the baseline marks online rows with the value 1, statics 2
        let run = rescale(&e.payload, &|k, x| if k == "p99_s" && x < 1.5 { 9.0 } else { x });
        broken.ingest(&e.bench, "bench", &run).unwrap();
    }
    let findings = benchlog::diff(&broken, &baseline, &ci_opts).unwrap();
    assert!(
        findings.iter().any(|f| f.kind == "ordering" && f.message.contains("burst-storm")),
        "inverted tail must be caught: {findings:?}"
    );
    // and dropping a bench from the index is a coverage finding
    let partial = BenchLog::open(dir.join("partial.jsonl"));
    let _ = std::fs::remove_file(partial.path());
    partial.ingest("fleet_churn", "bench", &entries[0].payload).unwrap();
    let findings = benchlog::diff(&partial, &baseline, &ci_opts).unwrap();
    assert!(
        findings.iter().any(|f| f.kind == "coverage" && f.message.contains("fleet_scale")),
        "missing bench must be a coverage finding: {findings:?}"
    );
}
