//! Churn integration: the whole online-fleet path — shared edge queue
//! (analytic + event-level), queue-aware allocation, churn timeline,
//! fingerprint-gated warm re-allocation — exercised through the public
//! API, artifact-free.

use qaci::coordinator::batcher::BatcherConfig;
use qaci::data::workload::Arrival;
use qaci::fleet::churn::{self, ChurnConfig, ChurnEvent, ChurnPolicy};
use qaci::fleet::{events, sim, FleetSimConfig, LaneSeedMix};
use qaci::opt::fleet::{self, AdmissionPricing, AgentSpec, FleetProblem, ProposedOptions};
use qaci::system::queue::{QueueDiscipline, QueueModel};
use qaci::system::Platform;

fn mixed(n: usize) -> FleetProblem {
    FleetProblem::new(Platform::fleet_edge(), AgentSpec::mixed_fleet(n))
}

/// Acceptance: under joins/leaves/bursts, online re-allocation achieves
/// strictly lower time-averaged fleet-weighted cost than the best static
/// allocation computed at t = 0.
#[test]
fn online_reallocation_beats_best_static_under_churn() {
    let cfg = ChurnConfig::default();
    let (tl, reports) = churn::compare(Platform::fleet_edge(), &cfg);
    assert!(tl.joins + tl.leaves + tl.bursts > 0, "default config must churn");
    let by = |p: ChurnPolicy| reports.iter().find(|r| r.policy == p).unwrap();
    let online = by(ChurnPolicy::Online);
    let best_static = by(ChurnPolicy::StaticEqual)
        .time_avg_cost
        .min(by(ChurnPolicy::StaticProposed).time_avg_cost);
    assert!(
        online.time_avg_cost < best_static,
        "online {} !< best static {}",
        online.time_avg_cost,
        best_static
    );
    assert!(online.reallocations > 0);
    // the same holds for the distortion upper bound
    let best_static_du = by(ChurnPolicy::StaticEqual)
        .time_avg_d_upper
        .min(by(ChurnPolicy::StaticProposed).time_avg_d_upper);
    assert!(online.time_avg_d_upper < best_static_du);
}

/// Acceptance: with churn disabled the online path reproduces the static
/// proposed allocation exactly — the fingerprint never changes, so the
/// warm re-solve never fires.
#[test]
fn online_without_churn_is_exactly_static_proposed() {
    let cfg = ChurnConfig { queue: None, ..ChurnConfig::default() }.without_churn();
    let tl = churn::timeline(&cfg);
    assert!(tl.events.iter().all(|&(_, e)| e == ChurnEvent::Tick));
    let online = churn::run_churn(Platform::fleet_edge(), &tl, ChurnPolicy::Online, &cfg);
    let statik = churn::run_churn(Platform::fleet_edge(), &tl, ChurnPolicy::StaticProposed, &cfg);
    assert_eq!(online.reallocations, 0);
    assert!(online.realloc_skipped > 0);
    assert_eq!(online.time_avg_cost, statik.time_avg_cost);
    assert_eq!(online.final_alloc.objective, statik.final_alloc.objective);
    for (a, b) in online.final_alloc.agents.iter().zip(&statik.final_alloc.agents) {
        assert_eq!(a.design.map(|d| d.b_hat), b.design.map(|d| d.b_hat));
        assert_eq!(a.server_share, b.server_share);
        assert_eq!(a.airtime_share, b.airtime_share);
    }
    // and both equal a direct static solve of the same fleet
    let direct = fleet::solve_proposed(
        &mixed(cfg.initial_agents)
            .with_link(cfg.link_rate_bps, cfg.link_base_latency_s),
    );
    assert_eq!(direct.objective, online.final_alloc.objective);
}

/// The same timeline replays identically, so policy comparisons are
/// apples-to-apples and reports are reproducible.
#[test]
fn churn_runs_are_deterministic() {
    let cfg = ChurnConfig::default();
    let tl = churn::timeline(&cfg);
    let a = churn::run_churn(Platform::fleet_edge(), &tl, ChurnPolicy::Online, &cfg);
    let b = churn::run_churn(Platform::fleet_edge(), &tl, ChurnPolicy::Online, &cfg);
    assert_eq!(a.time_avg_cost, b.time_avg_cost);
    assert_eq!(a.reallocations, b.reallocations);
    assert_eq!(a.cost_trace, b.cost_trace);
}

/// The analytic queue term behaves like a contention model should: it
/// can only cost bits at identical shares, and overload rejects cleanly
/// (finite penalty, no NaN poisoning) instead of admitting garbage.
#[test]
fn queue_aware_allocation_degrades_gracefully_with_load() {
    let n = 6;
    let mut last = f64::NEG_INFINITY;
    for rps in [0.0, 0.02, 0.05, 0.1, 0.5] {
        let fp = mixed(n)
            .with_queue(QueueModel::uniform(QueueDiscipline::Fifo, n, rps));
        let alloc = fleet::solve_equal_share(&fp);
        assert!(alloc.objective.is_finite(), "rps={rps}");
        assert!(
            alloc.objective >= last - 1e-12,
            "rps={rps}: more load cannot reduce equal-share cost"
        );
        last = alloc.objective;
    }
    // zero load with a queue attached equals no queue at all
    let with0 = fleet::solve_equal_share(
        &mixed(n).with_queue(QueueModel::uniform(QueueDiscipline::Fifo, n, 0.0)),
    );
    let without = fleet::solve_equal_share(&mixed(n));
    assert_eq!(with0.objective, without.objective);
}

/// Warm-started re-allocation is never worse than what it started from
/// and seats newcomers carved into an already-full allocation.
#[test]
fn warm_start_online_resolve_is_sound() {
    let fp = mixed(6);
    let cold = fleet::solve_proposed(&fp);
    let prev: Vec<Option<(f64, f64)>> = cold
        .agents
        .iter()
        .map(|a| Some((a.server_share, a.airtime_share)))
        .collect();
    let warm = fleet::solve_proposed_warm(&fp, &prev, ProposedOptions::default());
    assert!(warm.objective <= cold.objective + 1e-12);

    // population grows by two: the joiners arrive with None
    let grown = mixed(8);
    let mut prev_grown = prev;
    prev_grown.extend([None, None]);
    let warm8 = fleet::solve_proposed_warm(&grown, &prev_grown, ProposedOptions::default());
    for shares in [warm8.server_shares(), warm8.airtime_shares()] {
        assert!(shares.iter().all(|&s| (0.0..=1.0 + 1e-9).contains(&s)));
        assert!(shares.iter().sum::<f64>() <= 1.0 + 1e-9);
        assert!(shares[6] > 0.0 && shares[7] > 0.0, "newcomers unseated");
    }
}

/// End-to-end: the event-level shared queue in the serving loop agrees
/// qualitatively with the analytic model — serialization produces
/// visible waits and a longer tail, and never loses requests.
#[test]
fn shared_queue_serving_loop_end_to_end() {
    let fp = mixed(6);
    let alloc = fleet::solve_proposed(&fp);
    let base = FleetSimConfig {
        requests_per_agent: 10,
        arrival: Arrival::Batch,
        seed: 9,
        batcher: BatcherConfig::default(),
        queue: None,
        lane_mix: LaneSeedMix::default(),
    };
    let plain = sim::run(&fp, &alloc, &base);
    let queued = sim::run(
        &fp,
        &alloc,
        &FleetSimConfig { queue: Some(QueueDiscipline::Fifo), ..base },
    );
    assert_eq!(plain.served + plain.rejected as usize, 60);
    assert_eq!(queued.served, plain.served, "serialization must not drop requests");
    assert_eq!(queued.queue_wait_s.len(), queued.served);
    assert!(queued.queue_wait_s.max() > 0.0, "contention must surface as waits");
    assert!(plain.queue_wait_s.max() == 0.0, "no shared queue, no waits");
    assert!(queued.e2e_s.max() >= plain.e2e_s.max());
    // compute-side QoS still holds: waits are e2e, not compute
    assert_eq!(queued.qos_violations, 0);
}

/// Acceptance (event level): on the designated burst-storm scenario the
/// online policy beats the best static policy on p99 end-to-end delay by
/// better than 2× (measured ~11×) — frozen shares let the shared queue
/// diverge during bursts, online re-allocation keeps the tail bounded —
/// and on deadline-violation rate, while the analytic cost ordering
/// holds on the same timeline.
#[test]
fn event_level_burst_storm_online_wins_the_tail() {
    let cfg = ChurnConfig {
        initial_agents: 5,
        join_rps: 0.0,
        leave_rps_per_agent: 0.0,
        burst_rps: 0.04,
        burst_factor: 6.0,
        burst_duration_s: 60.0,
        arrival_rps: 0.04,
        seed: 7,
        ..ChurnConfig::default()
    };
    let tl = churn::timeline(&cfg);
    assert!(tl.bursts > 0, "scenario must burst");
    let base = Platform::fleet_edge();
    let by_event = |p| events::run_events(base, &tl, p, &cfg);
    let online = by_event(ChurnPolicy::Online);
    let equal = by_event(ChurnPolicy::StaticEqual);
    let statik = by_event(ChurnPolicy::StaticProposed);
    // conservation everywhere
    for r in [&online, &equal, &statik] {
        assert_eq!(r.arrivals, r.completed + r.rejected + r.dropped_departure);
        assert!(r.arrivals > 100, "storm must generate real traffic");
    }
    let best_static_p99 = equal.e2e_s.p99().min(statik.e2e_s.p99());
    assert!(
        online.e2e_s.p99() < best_static_p99 * 0.5,
        "online p99 {} vs best static {best_static_p99}",
        online.e2e_s.p99()
    );
    let best_static_viol = equal.violation_rate().min(statik.violation_rate());
    assert!(online.violation_rate() < best_static_viol);
    // the analytic replay orders the same way on this timeline
    let cost = |p| churn::run_churn(base, &tl, p, &cfg).time_avg_cost;
    let best_static_cost =
        cost(ChurnPolicy::StaticEqual).min(cost(ChurnPolicy::StaticProposed));
    assert!(cost(ChurnPolicy::Online) < best_static_cost);
}

/// The events-off analytic path is unaffected by the event-mode and
/// pricing machinery: the default config carries uniform pricing, whose
/// rejection penalty is exactly the pre-tier silicon-blind formula, and
/// the analytic churn replay scores identically whether or not the event
/// replay runs beside it.
#[test]
fn events_off_analytic_path_is_undisturbed() {
    let cfg = ChurnConfig::default();
    assert_eq!(cfg.pricing, AdmissionPricing::Uniform);
    let tl = churn::timeline(&cfg);
    let before = churn::run_churn(Platform::fleet_edge(), &tl, ChurnPolicy::Online, &cfg);
    // an event replay in between shares no state with the analytic one
    let _ = events::run_events(Platform::fleet_edge(), &tl, ChurnPolicy::Online, &cfg);
    let after = churn::run_churn(Platform::fleet_edge(), &tl, ChurnPolicy::Online, &cfg);
    assert_eq!(before.time_avg_cost, after.time_avg_cost);
    assert_eq!(before.cost_trace, after.cost_trace);
    // and the two replays agree on the re-allocation schedule
    let ev = events::run_events(Platform::fleet_edge(), &tl, ChurnPolicy::Online, &cfg);
    assert_eq!(ev.reallocations, before.reallocations);
    assert_eq!(ev.realloc_skipped, before.realloc_skipped);
}

/// Tier-aware pricing rides the churn stack end to end: with a 3-tier
/// ladder and tiered pricing the online policy still beats the best
/// static policy on analytic cost, and the per-agent event telemetry
/// shows phone-tier traffic being turned away (the operator trade) while
/// orin-tier agents keep completing.
#[test]
fn tiered_pricing_churn_runs_end_to_end() {
    let cfg = ChurnConfig {
        tiers: AgentSpec::tier_mix(2),
        pricing: AdmissionPricing::Tiered,
        initial_agents: 9,
        max_agents: 9,
        seed: 3,
        ..ChurnConfig::default()
    };
    let tl = churn::timeline(&cfg);
    let base = Platform::fleet_edge();
    let cost = |p| churn::run_churn(base, &tl, p, &cfg).time_avg_cost;
    let online_cost = cost(ChurnPolicy::Online);
    assert!(online_cost.is_finite());
    assert!(
        online_cost <= cost(ChurnPolicy::StaticEqual).min(cost(ChurnPolicy::StaticProposed)),
        "tiered pricing must not break the online advantage"
    );
    let ev = events::run_events(base, &tl, ChurnPolicy::Online, &cfg);
    assert_eq!(ev.arrivals, ev.completed + ev.rejected + ev.dropped_departure);
    let phone_rejected: u64 = ev
        .per_agent
        .iter()
        .filter(|a| a.tier == "phone")
        .map(|a| a.rejected)
        .sum();
    let orin_completed: u64 = ev
        .per_agent
        .iter()
        .filter(|a| a.tier == "orin")
        .map(|a| a.completed)
        .sum();
    assert!(phone_rejected > 0, "tiered pricing should turn phone traffic away");
    assert!(orin_completed > 0, "orin agents must keep completing");
}

/// Churn + queue discipline interact sanely: a priority queue can only
/// help the heavy classes relative to FIFO on the same timeline.
#[test]
fn priority_discipline_is_no_worse_for_online_cost() {
    let fifo_cfg = ChurnConfig { seed: 5, ..ChurnConfig::default() };
    let prio_cfg = ChurnConfig {
        queue: Some(QueueDiscipline::WeightedPriority),
        ..fifo_cfg.clone()
    };
    // same seed, same event structure (the timeline does not depend on
    // the queue discipline)
    let tl_fifo = churn::timeline(&fifo_cfg);
    let tl_prio = churn::timeline(&prio_cfg);
    assert_eq!(tl_fifo.events, tl_prio.events);
    let fifo = churn::run_churn(Platform::fleet_edge(), &tl_fifo, ChurnPolicy::Online, &fifo_cfg);
    let prio = churn::run_churn(Platform::fleet_edge(), &tl_prio, ChurnPolicy::Online, &prio_cfg);
    assert!(fifo.time_avg_cost.is_finite() && prio.time_avg_cost.is_finite());
    // both adapt; neither collapses (finite, positive, same event count)
    assert_eq!(fifo.events, prio.events);
    assert!(prio.time_avg_cost > 0.0 && fifo.time_avg_cost > 0.0);
}
