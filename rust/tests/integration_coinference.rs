//! Full-system integration: the co-inference coordinator end to end —
//! router → batcher → quantized agent stage → WLAN → edge stage →
//! telemetry — over real artifacts, single-threaded and pipelined.

use qaci::coordinator::batcher::BatcherConfig;
use qaci::coordinator::engine::{Engine, EngineConfig};
use qaci::coordinator::router::{QosPolicy, Router};
use qaci::coordinator::scheduler::{Algorithm, Scheduler};
use qaci::coordinator::server::PipelinedServer;
use qaci::data::eval::EvalSet;
use qaci::data::vocab::Vocab;
use qaci::data::workload::{generate, Arrival};
use qaci::quant::Scheme;
use qaci::runtime::executor::CoModel;
use qaci::runtime::Registry;
use qaci::system::channel::Channel;
use qaci::system::Platform;

fn registry() -> Option<Registry> {
    let dir = qaci::artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    Some(Registry::open(&dir).unwrap())
}

fn platform_for(model: &CoModel) -> Platform {
    // paper silicon, this repo's measured workloads
    Platform::paper_blip2().with_workload(model.agent_flops, model.server_flops)
}

#[test]
fn engine_serves_workload_with_qos() {
    let Some(reg) = registry() else { return };
    let mut model = CoModel::load(&reg, "blip2ish").unwrap();
    let eval = EvalSet::load(&reg.dir, &reg.manifest, "coco").unwrap();
    let vocab = Vocab::from_manifest(&reg.manifest).unwrap();
    let platform = platform_for(&model);
    let lambda = model.agent_weights.lambda;

    let scheduler = Scheduler::new(platform, lambda, Algorithm::Exact, Scheme::Uniform, 1);
    let router = Router::new(QosPolicy::paper_default(), scheduler);
    let requests = generate(24, eval.len(), Arrival::Poisson { lambda_rps: 50.0 }, 7);
    let n_req = requests.len();

    let mut engine = Engine::new(
        &mut model,
        router,
        &vocab,
        &eval,
        Channel::wlan_5ghz(3),
        EngineConfig { batcher: BatcherConfig { max_batch: 4, max_wait_s: 0.02 } },
    );
    let telemetry = engine.run(requests).unwrap();

    // conservation: every routed request produced exactly one record
    assert_eq!(telemetry.len() as u64 + telemetry.rejected, n_req as u64);
    assert_eq!(telemetry.rejected, 0);
    // the scheduler's plans must honor the simulated QoS for every record
    assert_eq!(telemetry.qos_violations(), 0, "QoS violated in simulation");
    // captions are real sentences from the model
    assert!(telemetry.records.iter().all(|r| !r.caption.is_empty()));
    // quality on the trained model should be well above noise
    // mixed QoS classes => some requests run at low bit-widths, so the
    // corpus score sits below the full-precision ceiling; random captions
    // score < 5, so 20 is a comfortable "system works" floor
    let cider = telemetry.cider_x100(&eval.refs);
    assert!(cider > 20.0, "corpus CIDEr x100 too low: {cider}");
    // all three classes present in rollups
    assert!(!telemetry.by_class().is_empty());
}

#[test]
fn pipelined_server_matches_engine_results() {
    let Some(reg) = registry() else { return };
    let eval = EvalSet::load(&reg.dir, &reg.manifest, "coco").unwrap();
    let model = CoModel::load(&reg, "blip2ish").unwrap();
    let platform = platform_for(&model);
    let lambda = model.agent_weights.lambda;
    drop(model);

    let scheduler = Scheduler::new(platform, lambda, Algorithm::Exact, Scheme::Uniform, 1);
    let mut server = PipelinedServer {
        artifacts: reg.dir.clone(),
        model_name: "blip2ish".into(),
        router: Router::new(QosPolicy::paper_default(), scheduler),
        batcher_cfg: BatcherConfig { max_batch: 4, max_wait_s: 0.02 },
        queue_depth: 4,
    };
    let requests = generate(16, eval.len(), Arrival::Batch, 5);
    let telemetry = server.run(requests, &eval).unwrap();

    assert_eq!(telemetry.len(), 16);
    assert_eq!(telemetry.qos_violations(), 0);
    assert!(telemetry.records.iter().all(|r| !r.caption.is_empty()));
    // determinism of content: the same requests through the single-thread
    // engine produce the same captions (order may differ)
    let Some(reg2) = registry() else { return };
    let mut model = CoModel::load(&reg2, "blip2ish").unwrap();
    let vocab = Vocab::from_manifest(&reg2.manifest).unwrap();
    let scheduler = Scheduler::new(platform, lambda, Algorithm::Exact, Scheme::Uniform, 1);
    let mut engine = Engine::new(
        &mut model,
        Router::new(QosPolicy::paper_default(), scheduler),
        &vocab,
        &eval,
        Channel::wlan_5ghz(3),
        EngineConfig { batcher: BatcherConfig { max_batch: 4, max_wait_s: 0.02 } },
    );
    let t2 = engine.run(generate(16, eval.len(), Arrival::Batch, 5)).unwrap();
    let mut a: Vec<(u64, String)> = telemetry
        .records
        .iter()
        .map(|r| (r.id, r.caption.clone()))
        .collect();
    let mut b: Vec<(u64, String)> = t2.records.iter().map(|r| (r.id, r.caption.clone())).collect();
    a.sort();
    b.sort();
    assert_eq!(a, b, "pipelined and single-thread captions diverge");
}

#[test]
fn lower_bit_budget_lowers_quality_but_saves_energy() {
    // squeeze the energy budget: the scheduler must pick fewer bits; the
    // corpus quality must drop; the simulated energy must drop too —
    // the paper's central trade-off, end to end through real inference
    let Some(reg) = registry() else { return };
    let eval = EvalSet::load(&reg.dir, &reg.manifest, "coco").unwrap();
    let vocab = Vocab::from_manifest(&reg.manifest).unwrap();

    // budgets that actually bind on this platform: anchor on the
    // minimum-energy plans at 6 and 16 bits under a fixed delay budget
    let probe = CoModel::load(&reg, "blip2ish").unwrap();
    let platform_probe = platform_for(&probe);
    let t0 = 1.2 * platform_probe.min_delay(16.0);
    let prob = qaci::opt::Problem::new(platform_probe, probe.agent_weights.lambda, t0, 1e9);
    let e_tight = prob.plan_frequencies(6.0).unwrap().energy * 1.05;
    let e_loose = prob.plan_frequencies(16.0).unwrap().energy * 1.50;
    assert!(e_loose > e_tight);
    drop(probe);

    let mut run_with_budget = |e0: f64| -> (f64, f64, f64) {
        let mut model = CoModel::load(&reg, "blip2ish").unwrap();
        let platform = platform_for(&model);
        let lambda = model.agent_weights.lambda;
        let scheduler = Scheduler::new(platform, lambda, Algorithm::Exact, Scheme::Uniform, 1);
        let router = Router::new(QosPolicy::uniform(t0, e0), scheduler);
        let mut engine = Engine::new(
            &mut model,
            router,
            &vocab,
            &eval,
            Channel::ideal(),
            EngineConfig::default(),
        );
        let t = engine.run(generate(20, eval.len(), Arrival::Batch, 11)).unwrap();
        assert_eq!(t.qos_violations(), 0);
        let bits = t.records.iter().map(|r| r.b_hat as f64).sum::<f64>() / t.len() as f64;
        (t.cider_x100(&eval.refs), t.total_energy_j() / t.len() as f64, bits)
    };
    let (cider_tight, energy_tight, bits_tight) = run_with_budget(e_tight);
    let (cider_loose, energy_loose, bits_loose) = run_with_budget(e_loose);
    assert!(
        bits_loose > bits_tight,
        "loose budget should afford more bits: {bits_tight} vs {bits_loose}"
    );
    assert!(
        cider_loose > cider_tight,
        "quality should improve with budget: {cider_tight} vs {cider_loose}"
    );
    assert!(
        energy_loose > energy_tight,
        "energy should grow with budget: {energy_tight} vs {energy_loose}"
    );
}
