//! Daemon integration: the closed-loop serving control plane — bounded
//! telemetry epochs, measured admission pricing, re-solve hysteresis,
//! deferred re-solves — plus the satellite surfaces it rides on
//! (closed-loop arrivals, per-request energy accounting, per-server
//! airtime pins and queue-discipline overrides) exercised through the
//! public API, artifact-free.

use qaci::fleet::churn::{self, ChurnConfig, ChurnPolicy};
use qaci::fleet::daemon::run_daemon;
use qaci::fleet::{events, DaemonConfig};
use qaci::opt::fleet::{AdmissionPricing, AgentSpec, FleetProblem, FleetSpec, ServerSpec, SolveRequest};
use qaci::system::queue::{QueueDiscipline, QueueModel};
use qaci::system::Platform;
use std::collections::hash_map::DefaultHasher;
use std::hash::{Hash, Hasher};

fn base() -> Platform {
    Platform::fleet_edge()
}

/// The designated burst-storm workload (shared with the churn and
/// daemon benches): pure burst churn against a loaded queue.
fn storm(seed: u64) -> ChurnConfig {
    ChurnConfig {
        initial_agents: 5,
        join_rps: 0.0,
        leave_rps_per_agent: 0.0,
        burst_rps: 0.04,
        burst_factor: 6.0,
        burst_duration_s: 60.0,
        arrival_rps: 0.04,
        seed,
        ..ChurnConfig::default()
    }
}

fn spec_hash(spec: &FleetSpec) -> u64 {
    let mut h = DefaultHasher::new();
    spec.hash(&mut h);
    h.finish()
}

/// Acceptance: same seed + config ⇒ byte-identical transcript, and the
/// epoch snapshots tile the horizon exactly (every arrival lands in one
/// epoch; the graceful drain admits nothing new).
#[test]
fn daemon_replays_byte_identically_and_tiles_the_horizon() {
    let cfg = DaemonConfig {
        churn: ChurnConfig { pricing: AdmissionPricing::Measured, ..storm(7) },
        ..DaemonConfig::default()
    };
    let a = run_daemon(base(), &cfg);
    let b = run_daemon(base(), &cfg);
    assert_eq!(a.transcript, b.transcript, "daemon transcript must be deterministic");
    assert_eq!(a.epochs.len(), cfg.epochs);
    let epoch_arrivals: u64 = a.epochs.iter().map(|e| e.arrivals).sum();
    assert_eq!(epoch_arrivals, a.report.arrivals);
    // graceful shutdown drained everything to a terminal state
    assert_eq!(
        a.report.arrivals,
        a.report.completed + a.report.rejected + a.report.dropped_departure
    );
    assert!(a.report.arrivals > 100, "storm must generate real traffic");
}

/// Acceptance (the tentpole ordering, through the public API): on the
/// burst storm the hysteresis daemon takes at most half of the
/// resolve-always daemon's solves while its fleet p99 end-to-end delay
/// stays within 1.5× — skipped solves are the cheap ones.
#[test]
fn hysteresis_halves_the_solve_count_at_bounded_tail_cost() {
    let hyst = DaemonConfig {
        churn: ChurnConfig { pricing: AdmissionPricing::Measured, ..storm(7) },
        ..DaemonConfig::default()
    };
    let always = DaemonConfig { resolve_always: true, ..hyst.clone() };
    let h = run_daemon(base(), &hyst);
    let a = run_daemon(base(), &always);
    assert!(a.resolves_taken > 0, "storm must force re-solves");
    assert!(
        2 * h.resolves_taken <= a.resolves_taken,
        "hysteresis took {} of {}",
        h.resolves_taken,
        a.resolves_taken
    );
    assert!(h.skipped_cooldown + h.skipped_gain > 0);
    assert!(
        h.report.e2e_s.p99() <= a.report.e2e_s.p99() * 1.5,
        "hysteresis p99 {} blew past 1.5x of {}",
        h.report.e2e_s.p99(),
        a.report.e2e_s.p99()
    );
}

/// The control-plane decisions surface in the metrics capture: epoch
/// and resolve counters mirror the report, and every gain-skip ran the
/// frozen-shares probe.
#[test]
fn daemon_metrics_mirror_the_decisions() {
    let cfg = DaemonConfig {
        churn: ChurnConfig { pricing: AdmissionPricing::Measured, ..storm(7) },
        ..DaemonConfig::default()
    };
    let r = run_daemon(base(), &cfg);
    assert_eq!(r.metrics.counter("daemon.epochs"), cfg.epochs as u64);
    assert_eq!(r.metrics.counter("daemon.resolve.taken"), r.resolves_taken as u64);
    assert_eq!(
        r.metrics.counter("daemon.resolve.skipped.cooldown"),
        r.skipped_cooldown as u64
    );
    assert_eq!(r.metrics.counter("daemon.resolve.skipped.gain"), r.skipped_gain as u64);
    if r.skipped_gain > 0 {
        assert!(
            r.metrics.counter("solver.probe.frozen") >= r.skipped_gain as u64,
            "every gain-skip prices the frozen shares"
        );
    }
    assert!(r.transcript.contains("epoch 1 "), "epochs must be logged");
    assert!(r.transcript.contains("shutdown "), "shutdown must be logged");
}

/// Closed-loop clients ride the daemon end to end: one outstanding
/// request per agent, re-armed at completion, still conserving every
/// request through epochs, re-solves and the graceful drain.
#[test]
fn daemon_serves_closed_loop_clients() {
    let cfg = DaemonConfig {
        churn: ChurnConfig {
            closed_loop: true,
            pricing: AdmissionPricing::Measured,
            ..storm(7)
        },
        ..DaemonConfig::default()
    };
    let r = run_daemon(base(), &cfg);
    assert!(r.report.arrivals > 0, "closed-loop clients must generate traffic");
    assert_eq!(
        r.report.arrivals,
        r.report.completed + r.report.rejected + r.report.dropped_departure
    );
    let epoch_arrivals: u64 = r.epochs.iter().map(|e| e.arrivals).sum();
    assert_eq!(epoch_arrivals, r.report.arrivals);
}

/// Open vs closed arrivals on the same seed: the churn timeline is
/// identical (arrival modelling never perturbs the event structure) and
/// both modes conserve requests, but the closed loop admits no agent's
/// second request before its first completes.
#[test]
fn open_and_closed_arrivals_share_the_timeline_and_conserve() {
    let open = storm(11);
    let closed = ChurnConfig { closed_loop: true, ..open.clone() };
    assert_eq!(churn::timeline(&open).events, churn::timeline(&closed).events);
    for cfg in [&open, &closed] {
        let tl = churn::timeline(cfg);
        let r = events::run_events(base(), &tl, ChurnPolicy::Online, cfg);
        assert!(r.arrivals > 0);
        assert_eq!(r.arrivals, r.completed + r.rejected + r.dropped_departure);
    }
}

/// Per-request energy accounting rides the daemon: fleet totals roll up
/// from the per-agent rollups, and the epoch deltas never overshoot the
/// drained total (the post-horizon drain still completes work).
#[test]
fn energy_accounting_rolls_up_through_the_daemon() {
    let cfg = DaemonConfig {
        churn: ChurnConfig { pricing: AdmissionPricing::Measured, ..storm(7) },
        ..DaemonConfig::default()
    };
    let r = run_daemon(base(), &cfg);
    assert!(r.report.energy_j > 0.0, "completed requests must cost energy");
    let per_agent: f64 = r.report.per_agent.iter().map(|a| a.energy_j).sum();
    assert!(
        (r.report.energy_j - per_agent).abs() <= 1e-9 * r.report.energy_j.max(1.0),
        "fleet energy {} vs per-agent sum {per_agent}",
        r.report.energy_j
    );
    let epoch_energy: f64 = r.epochs.iter().map(|e| e.energy_j).sum();
    assert!(
        epoch_energy <= r.report.energy_j + 1e-9,
        "epoch deltas {epoch_energy} overshoot the drained total {}",
        r.report.energy_j
    );
    assert!(r.report.energy_per_request_j() > 0.0);
}

/// Per-server airtime pins through the public API: each pinned server's
/// agents never sum past its reserved slice, and the pins participate
/// in the spec fingerprint (so churn's gate sees them move).
#[test]
fn airtime_pins_cap_the_medium_and_move_the_fingerprint() {
    let mut spec = FleetSpec::new(base(), AgentSpec::mixed_fleet(8));
    spec.servers = vec![
        ServerSpec { airtime_fraction: Some(0.6), ..ServerSpec::default() },
        ServerSpec { airtime_fraction: Some(0.4), ..ServerSpec::default() },
    ];
    let fp = FleetProblem::from_spec(spec.clone());
    let alloc = fp.solve(&SolveRequest::default());
    assert!(alloc.objective.is_finite());
    for (k, srv) in fp.servers.iter().enumerate() {
        let pin = srv.airtime_fraction.unwrap();
        let sum: f64 = alloc
            .agents
            .iter()
            .enumerate()
            .filter(|(i, _)| alloc.placement.assignment[*i] == k)
            .map(|(_, a)| a.airtime_share)
            .sum();
        assert!(sum <= pin + 1e-9, "server {k}: airtime {sum} exceeds pin {pin}");
    }
    // pins are fingerprinted: moving one, or dropping it, re-hashes
    let mut moved = spec.clone();
    moved.servers[0].airtime_fraction = Some(0.5);
    let mut dropped = spec.clone();
    dropped.servers[0].airtime_fraction = None;
    assert_ne!(spec_hash(&spec), spec_hash(&moved));
    assert_ne!(spec_hash(&spec), spec_hash(&dropped));
}

/// Per-server queue overrides through the public API: an override equal
/// to the fleet-wide discipline is the identity (bit for bit), a
/// different one solves cleanly, and both participate in the spec
/// fingerprint.
#[test]
fn queue_overrides_are_identity_when_redundant_and_fingerprinted() {
    let queued = |servers: Vec<ServerSpec>| {
        let mut spec = FleetSpec::new(base(), AgentSpec::mixed_fleet(8));
        spec.servers = servers;
        spec.queue = Some(QueueModel::uniform(QueueDiscipline::Fifo, 8, 0.02));
        spec
    };
    let plain = queued(ServerSpec::identical(2));
    let redundant = queued(vec![
        ServerSpec { queue: Some(QueueDiscipline::Fifo), ..ServerSpec::default() };
        2
    ]);
    let a = FleetProblem::from_spec(plain.clone()).solve(&SolveRequest::default());
    let b = FleetProblem::from_spec(redundant.clone()).solve(&SolveRequest::default());
    assert_eq!(a.objective, b.objective, "redundant override must be the identity");
    for (x, y) in a.agents.iter().zip(&b.agents) {
        assert_eq!(x.server_share, y.server_share);
        assert_eq!(x.airtime_share, y.airtime_share);
    }
    // a genuinely different discipline on one box still solves...
    let mixed = queued(vec![
        ServerSpec { queue: Some(QueueDiscipline::WeightedPriority), ..ServerSpec::default() },
        ServerSpec::default(),
    ]);
    let m = FleetProblem::from_spec(mixed.clone()).solve(&SolveRequest::default());
    assert!(m.objective.is_finite());
    // ...and the override (redundant or not) moves the fingerprint
    assert_ne!(spec_hash(&plain), spec_hash(&redundant));
    assert_ne!(spec_hash(&plain), spec_hash(&mixed));
}
