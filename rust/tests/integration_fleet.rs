//! Fleet integration: the whole multi-agent path — contention model,
//! joint allocator, admission control, serving loop — exercised through
//! the public API, artifact-free.

use qaci::coordinator::batcher::BatcherConfig;
use qaci::data::workload::Arrival;
use qaci::fleet::{sim, FleetSimConfig};
use qaci::opt::fleet::{self, AgentSpec, FleetAlgorithm, FleetProblem};
use qaci::opt::{bisection, Problem};
use qaci::system::Platform;

fn mixed(n: usize) -> FleetProblem {
    FleetProblem::new(Platform::fleet_edge(), AgentSpec::mixed_fleet(n))
}

/// The headline reduction: a fleet of one with the medium to itself is
/// exactly the paper's single-pair joint design.
#[test]
fn fleet_of_one_is_the_single_agent_design() {
    let fp = mixed(1).ideal_link();
    let spec = fp.agents[0];
    let single = bisection::solve(&Problem::new(
        Platform::fleet_edge(),
        spec.lambda,
        spec.t0,
        spec.e0,
    ))
    .expect("single-agent problem feasible");
    let alloc = fleet::solve_proposed(&fp);
    let d = alloc.agents[0].design.expect("admitted");
    assert_eq!(d.b_hat, single.design.b_hat);
    assert!((d.f - single.design.f).abs() / single.design.f < 1e-9);
    assert!((d.f_tilde - single.design.f_tilde).abs() / single.design.f_tilde < 1e-9);
    assert!((alloc.agents[0].server_share - 1.0).abs() < 1e-12);
    assert!((alloc.agents[0].airtime_share - 1.0).abs() < 1e-12);
}

/// Proposed vs. baselines across fleet sizes: never worse than the equal
/// split, strictly better once the shared server is contended (N >= 4),
/// and at least as good as the random baseline's average.
#[test]
fn proposed_dominates_baselines_across_fleet_sizes() {
    for n in [1usize, 2, 4, 8, 16] {
        let fp = mixed(n);
        let proposed = fleet::solve_proposed(&fp);
        let equal = fleet::solve_equal_share(&fp);
        assert!(
            proposed.objective <= equal.objective + 1e-15,
            "N={n}: {} vs {}",
            proposed.objective,
            equal.objective
        );
        if n >= 4 {
            assert!(
                proposed.objective < equal.objective * 0.999,
                "N={n}: no strict improvement ({} vs {})",
                proposed.objective,
                equal.objective
            );
            assert!(proposed.weighted_d_upper(&fp) < equal.weighted_d_upper(&fp));
        }
        let random_mean = fleet::feasible_random_mean(&fp, 10, 9);
        assert!(
            random_mean >= proposed.objective - 1e-15,
            "N={n}: random mean {random_mean} beat proposed {}",
            proposed.objective
        );
    }
}

/// End-to-end serving pass at N = 8: allocation, per-agent routers and
/// batchers, shared jittered medium, fleet telemetry rollup.
#[test]
fn fleet_serving_loop_end_to_end() {
    let fp = mixed(8);
    let alloc = fleet::solve_proposed(&fp);
    assert!(alloc.admitted >= 6, "water-filling should seat most of N=8");
    let report = sim::run(
        &fp,
        &alloc,
        &FleetSimConfig {
            requests_per_agent: 12,
            arrival: Arrival::Poisson { lambda_rps: 1.5 },
            seed: 5,
            batcher: BatcherConfig::default(),
            queue: None,
        },
    );
    assert_eq!(report.served + report.rejected as usize, 8 * 12);
    assert_eq!(report.served, alloc.admitted * 12);
    assert_eq!(report.e2e_s.len(), report.served);
    // compute-side QoS holds by construction; e2e adds queue + shared link
    assert_eq!(report.qos_violations, 0);
    assert!(report.e2e_s.p95() >= report.e2e_s.p50());
    assert!(report.total_energy_j > 0.0);
    assert_eq!(report.weighted_gap, alloc.objective);
    // per-agent rollups are consistent with the fleet rollup
    let per_agent_served: usize = report.per_agent.iter().map(|a| a.served).sum();
    assert_eq!(per_agent_served, report.served);
    for a in &report.per_agent {
        if a.admitted {
            assert!(a.b_hat >= 1 && a.b_hat <= fp.base.b_max);
        } else {
            assert_eq!(a.served, 0);
        }
    }
}

/// Overload regime: the equal split serves nobody at N = 32 on one paper
/// server, while the proposed allocator's admission control keeps the
/// high-priority slice of the fleet alive.
#[test]
fn admission_control_under_overload() {
    let fp = mixed(32);
    let equal = fleet::solve_equal_share(&fp);
    assert_eq!(equal.admitted, 0);
    let proposed = fleet::solve_proposed(&fp);
    assert!(proposed.admitted >= 4, "expected a served subset, got {}", proposed.admitted);
    assert!(proposed.objective < equal.objective - 1e-9);
    // shares stay a valid partition under heavy reallocation
    for shares in [proposed.server_shares(), proposed.airtime_shares()] {
        assert!(shares.iter().all(|&s| (0.0..=1.0 + 1e-9).contains(&s)));
        assert!(shares.iter().sum::<f64>() <= 1.0 + 1e-9);
    }
    // the serving loop surfaces the rejected traffic
    let report = sim::run(
        &fp,
        &proposed,
        &FleetSimConfig {
            requests_per_agent: 4,
            arrival: Arrival::Batch,
            seed: 2,
            batcher: BatcherConfig::default(),
            queue: None,
        },
    );
    assert_eq!(report.rejected, ((32 - proposed.admitted) * 4) as u64);
}

/// The three named algorithms all produce valid allocations via the
/// dispatch entry point.
#[test]
fn algorithm_dispatch_and_parsing() {
    let fp = mixed(4);
    for (name, algorithm) in [
        ("proposed", FleetAlgorithm::Proposed),
        ("equal-share", FleetAlgorithm::EqualShare),
        ("feasible-random", FleetAlgorithm::FeasibleRandom),
    ] {
        assert_eq!(FleetAlgorithm::parse(name), Some(algorithm));
        assert_eq!(algorithm.name(), name);
        let alloc = fleet::solve(&fp, algorithm, 13);
        assert_eq!(alloc.agents.len(), 4);
        assert!(alloc.objective.is_finite());
    }
    assert_eq!(FleetAlgorithm::parse("equal"), Some(FleetAlgorithm::EqualShare));
    assert_eq!(FleetAlgorithm::parse("nope"), None);
}
