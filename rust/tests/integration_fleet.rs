//! Fleet integration: the whole multi-agent path — contention model,
//! joint allocator, admission control, heterogeneous silicon tiers,
//! serving loop, and the `qaci fleet` CLI binary — exercised through the
//! public API and a spawned subprocess, artifact-free.

use qaci::coordinator::batcher::BatcherConfig;
use qaci::data::workload::Arrival;
use qaci::fleet::{sim, FleetSimConfig, LaneSeedMix};
use qaci::opt::fleet::{self, AgentSpec, FleetAlgorithm, FleetProblem, SolveRequest};
use qaci::opt::{bisection, Problem};
use qaci::system::Platform;

fn mixed(n: usize) -> FleetProblem {
    FleetProblem::new(Platform::fleet_edge(), AgentSpec::mixed_fleet(n))
}

fn tiered(n: usize, spread: usize) -> FleetProblem {
    FleetProblem::new(
        Platform::fleet_edge(),
        AgentSpec::tiered_fleet(n, &AgentSpec::tier_mix(spread)),
    )
}

/// The headline reduction: a fleet of one with the medium to itself is
/// exactly the paper's single-pair joint design.
#[test]
fn fleet_of_one_is_the_single_agent_design() {
    let fp = mixed(1).ideal_link();
    let spec = fp.agents[0];
    let single = bisection::solve(&Problem::new(
        Platform::fleet_edge(),
        spec.lambda,
        spec.t0,
        spec.e0,
    ))
    .expect("single-agent problem feasible");
    let alloc = fleet::solve_proposed(&fp);
    let d = alloc.agents[0].design.expect("admitted");
    assert_eq!(d.b_hat, single.design.b_hat);
    assert!((d.f - single.design.f).abs() / single.design.f < 1e-9);
    assert!((d.f_tilde - single.design.f_tilde).abs() / single.design.f_tilde < 1e-9);
    assert!((alloc.agents[0].server_share - 1.0).abs() < 1e-12);
    assert!((alloc.agents[0].airtime_share - 1.0).abs() < 1e-12);
}

/// Proposed vs. baselines across fleet sizes: never worse than the equal
/// split, strictly better once the shared server is contended (N >= 4),
/// and at least as good as the random baseline's average.
#[test]
fn proposed_dominates_baselines_across_fleet_sizes() {
    for n in [1usize, 2, 4, 8, 16] {
        let fp = mixed(n);
        let proposed = fleet::solve_proposed(&fp);
        let equal = fleet::solve_equal_share(&fp);
        assert!(
            proposed.objective <= equal.objective + 1e-15,
            "N={n}: {} vs {}",
            proposed.objective,
            equal.objective
        );
        if n >= 4 {
            assert!(
                proposed.objective < equal.objective * 0.999,
                "N={n}: no strict improvement ({} vs {})",
                proposed.objective,
                equal.objective
            );
            assert!(proposed.weighted_d_upper(&fp) < equal.weighted_d_upper(&fp));
        }
        let random_mean = fleet::feasible_random_mean(&fp, 10, 9);
        assert!(
            random_mean >= proposed.objective - 1e-15,
            "N={n}: random mean {random_mean} beat proposed {}",
            proposed.objective
        );
    }
}

/// End-to-end serving pass at N = 8: allocation, per-agent routers and
/// batchers, shared jittered medium, fleet telemetry rollup.
#[test]
fn fleet_serving_loop_end_to_end() {
    let fp = mixed(8);
    let alloc = fleet::solve_proposed(&fp);
    assert!(alloc.admitted >= 6, "water-filling should seat most of N=8");
    let report = sim::run(
        &fp,
        &alloc,
        &FleetSimConfig {
            requests_per_agent: 12,
            arrival: Arrival::Poisson { lambda_rps: 1.5 },
            seed: 5,
            batcher: BatcherConfig::default(),
            queue: None,
            lane_mix: LaneSeedMix::default(),
        },
    );
    assert_eq!(report.served + report.rejected as usize, 8 * 12);
    assert_eq!(report.served, alloc.admitted * 12);
    assert_eq!(report.e2e_s.len(), report.served);
    // compute-side QoS holds by construction; e2e adds queue + shared link
    assert_eq!(report.qos_violations, 0);
    assert!(report.e2e_s.p95() >= report.e2e_s.p50());
    assert!(report.total_energy_j > 0.0);
    assert_eq!(report.weighted_gap, alloc.objective);
    // per-agent rollups are consistent with the fleet rollup
    let per_agent_served: usize = report.per_agent.iter().map(|a| a.served).sum();
    assert_eq!(per_agent_served, report.served);
    for a in &report.per_agent {
        if a.admitted {
            assert!(a.b_hat >= 1 && a.b_hat <= fp.base.b_max);
        } else {
            assert_eq!(a.served, 0);
        }
    }
}

/// Overload regime: the equal split serves nobody at N = 32 on one paper
/// server, while the proposed allocator's admission control keeps the
/// high-priority slice of the fleet alive.
#[test]
fn admission_control_under_overload() {
    let fp = mixed(32);
    let equal = fleet::solve_equal_share(&fp);
    assert_eq!(equal.admitted, 0);
    let proposed = fleet::solve_proposed(&fp);
    assert!(proposed.admitted >= 4, "expected a served subset, got {}", proposed.admitted);
    assert!(proposed.objective < equal.objective - 1e-9);
    // shares stay a valid partition under heavy reallocation
    for shares in [proposed.server_shares(), proposed.airtime_shares()] {
        assert!(shares.iter().all(|&s| (0.0..=1.0 + 1e-9).contains(&s)));
        assert!(shares.iter().sum::<f64>() <= 1.0 + 1e-9);
    }
    // the serving loop surfaces the rejected traffic
    let report = sim::run(
        &fp,
        &proposed,
        &FleetSimConfig {
            requests_per_agent: 4,
            arrival: Arrival::Batch,
            seed: 2,
            batcher: BatcherConfig::default(),
            queue: None,
            lane_mix: LaneSeedMix::default(),
        },
    );
    assert_eq!(report.rejected, ((32 - proposed.admitted) * 4) as u64);
}

/// Acceptance (regression): the uniform-Orin ladder *is* the pre-tier
/// homogeneous fleet — identical specs and identical allocations across
/// sizes, including a queue-free churn-style warm path.
#[test]
fn uniform_tier_fleet_reproduces_homogeneous_results_exactly() {
    for n in [1usize, 4, 8, 16, 32] {
        let a = fleet::solve_proposed(&tiered(n, 0));
        let b = fleet::solve_proposed(&mixed(n));
        assert_eq!(a.objective, b.objective, "N={n}");
        assert_eq!(a.admitted, b.admitted, "N={n}");
        for (x, y) in a.agents.iter().zip(&b.agents) {
            assert_eq!(x.design.map(|d| d.b_hat), y.design.map(|d| d.b_hat));
            assert_eq!(x.server_share, y.server_share);
            assert_eq!(x.airtime_share, y.airtime_share);
        }
    }
}

/// Acceptance: on the silicon ladder the proposed allocator strictly
/// beats the equal split, with the absolute margin non-decreasing in
/// tier spread and strictly widening at N = 7 (the first size that
/// seats a phone-class agent).
#[test]
fn hetero_fleet_margin_widens_with_tier_spread() {
    let margin = |n: usize, spread: usize| {
        let fp = tiered(n, spread);
        let eq = fleet::solve_equal_share(&fp);
        let pr = fleet::solve_proposed(&fp);
        assert!(pr.objective <= eq.objective + 1e-12, "N={n} spread={spread}");
        eq.objective - pr.objective
    };
    for n in [4usize, 6, 7] {
        let (m0, m1, m2) = (margin(n, 0), margin(n, 1), margin(n, 2));
        assert!(m0 <= m1 + 1e-12 && m1 <= m2 + 1e-12, "N={n}: {m0} {m1} {m2}");
        assert!(m1 > 0.0, "N={n}: mixed-tier fleet must show a strict margin");
    }
    assert!(margin(7, 2) > margin(7, 1) * 1.5, "margin must widen at full spread");
    // the mechanism: the equal split starves exactly the phone-class
    // interactive agent while the proposed design seats the whole fleet
    let fp = tiered(7, 2);
    let eq = fleet::solve_equal_share(&fp);
    let pr = fleet::solve_proposed(&fp);
    assert_eq!(pr.admitted, 7);
    assert_eq!(eq.admitted, 6);
    assert!(eq.agents[6].design.is_none(), "equal split should reject the phone agent");
    assert_eq!(fp.agents[6].device.tier, "phone");
}

// ---------------------------------------------------------------------------
// CLI end-to-end (spawns the qaci binary; fleet paths are artifact-free)
// ---------------------------------------------------------------------------

fn qaci(args: &[&str]) -> (String, bool) {
    let out = std::process::Command::new(env!("CARGO_BIN_EXE_qaci"))
        .args(args)
        .output()
        .expect("qaci binary runs");
    (String::from_utf8_lossy(&out.stdout).into_owned(), out.status.success())
}

fn parse_weighted_gap(stdout: &str) -> f64 {
    let tail = stdout
        .split("weighted gap ")
        .nth(1)
        .unwrap_or_else(|| panic!("no weighted gap in output:\n{stdout}"));
    let token = tail.split_whitespace().next().expect("gap value token");
    token.parse::<f64>().unwrap_or_else(|e| panic!("unparseable gap {token:?}: {e}"))
}

/// `qaci fleet --tiers` end to end: parseable output, finite costs, and
/// the hetero margin over equal-share strictly exceeding the uniform
/// one — the CLI surface of the tier acceptance property.
#[test]
fn cli_fleet_hetero_vs_uniform_margin_ordering() {
    let gap = |tiers: &str, algorithm: &str| -> f64 {
        let (stdout, ok) = qaci(&[
            "fleet", "--agents", "7", "--tiers", tiers, "--algorithm", algorithm,
            "--requests", "4",
        ]);
        assert!(ok, "qaci fleet --tiers {tiers} --algorithm {algorithm} failed:\n{stdout}");
        assert!(stdout.contains("per-agent allocation"), "table missing:\n{stdout}");
        let gap = parse_weighted_gap(&stdout);
        assert!(gap.is_finite() && gap >= 0.0, "gap {gap} not finite");
        gap
    };
    let uniform_margin = gap("orin", "equal") - gap("orin", "proposed");
    let hetero_margin =
        gap("orin,xavier,phone", "equal") - gap("orin,xavier,phone", "proposed");
    assert!(uniform_margin >= 0.0);
    assert!(
        hetero_margin > uniform_margin * 2.0,
        "hetero margin {hetero_margin} does not dominate uniform {uniform_margin}"
    );
    // the hetero run surfaces the tier column
    let (stdout, _) = qaci(&["fleet", "--agents", "7", "--tiers", "orin,xavier,phone",
        "--requests", "4"]);
    for tier in ["orin", "xavier", "phone"] {
        assert!(stdout.contains(tier), "tier {tier} missing from CLI table:\n{stdout}");
    }
}

/// `qaci fleet --churn --queue --tiers` smoke: the full online
/// re-allocation comparison on heterogeneous silicon completes, prints
/// all three policies with finite costs, and the online policy wins
/// (exit code 0).
#[test]
fn cli_fleet_churn_queue_tiers_smoke() {
    let (stdout, ok) = qaci(&[
        "fleet", "--churn", "--queue", "fifo", "--tiers", "orin,xavier,phone",
        "--horizon", "240", "--seed", "0",
    ]);
    assert!(ok, "churn CLI exited nonzero:\n{stdout}");
    assert!(stdout.contains("tiers [orin,xavier,phone]"), "{stdout}");
    for policy in ["static-equal", "static-proposed", "online-proposed"] {
        assert!(stdout.contains(policy), "policy {policy} missing:\n{stdout}");
    }
    assert!(
        stdout.contains("OK: online re-allocation beats the best static policy"),
        "online did not win:\n{stdout}"
    );
    // every cost cell in the comparison table parses to a finite f64
    let costs: Vec<f64> = stdout
        .lines()
        .filter(|l| l.contains("static-") || l.contains("online-"))
        .filter_map(|l| l.split_whitespace().nth(1).map(str::to_owned))
        .map(|tok| tok.parse::<f64>().unwrap_or_else(|e| panic!("bad cost {tok:?}: {e}")))
        .collect();
    assert_eq!(costs.len(), 3, "expected one cost per policy:\n{stdout}");
    assert!(costs.iter().all(|c| c.is_finite() && *c >= 0.0));
    // unknown tiers are rejected up front
    let (_, ok) = qaci(&["fleet", "--tiers", "tpu"]);
    assert!(!ok, "unknown tier must fail");
}

/// `qaci fleet --churn --events` acceptance: the CLI prints per-policy
/// p50/p95/p99 end-to-end delay and the deadline-violation rate from the
/// event-level replay, on top of the analytic comparison.
#[test]
fn cli_fleet_churn_events_prints_tail_telemetry() {
    let (stdout, ok) = qaci(&[
        "fleet", "--churn", "--events", "--horizon", "240", "--seed", "0",
    ]);
    assert!(ok, "churn --events CLI exited nonzero:\n{stdout}");
    assert!(stdout.contains("event-level telemetry"), "event table missing:\n{stdout}");
    for col in ["e2e p50", "e2e p95", "e2e p99", "wait p99", "deadline viol"] {
        assert!(stdout.contains(col), "column {col} missing:\n{stdout}");
    }
    // one event row per policy, and the violation column parses as a
    // percentage for each
    let table = stdout.split("event-level telemetry").nth(1).unwrap();
    let comparison = table.split("policy comparison").next().unwrap();
    for policy in ["static-equal", "static-proposed", "online-proposed"] {
        let row = comparison
            .lines()
            .find(|l| l.trim_start().starts_with(policy))
            .unwrap_or_else(|| panic!("no event row for {policy}:\n{stdout}"));
        let pct = row
            .split_whitespace()
            .last()
            .unwrap()
            .trim_end_matches('%')
            .parse::<f64>()
            .unwrap_or_else(|e| panic!("bad violation cell in {row:?}: {e}"));
        assert!((0.0..=100.0).contains(&pct), "{policy}: violation {pct}%");
    }
    // without --events the table is absent (analytic fast path only)
    let (stdout, ok) = qaci(&["fleet", "--churn", "--horizon", "240", "--seed", "0"]);
    assert!(ok);
    assert!(!stdout.contains("event-level telemetry"));
}

/// `--admission-pricing` is surfaced and validated on both fleet paths.
#[test]
fn cli_admission_pricing_flag() {
    let (stdout, ok) = qaci(&[
        "fleet", "--agents", "9", "--tiers", "orin,xavier,phone",
        "--admission-pricing", "tiered", "--requests", "4",
    ]);
    assert!(ok, "tiered pricing run failed:\n{stdout}");
    assert!(stdout.contains("pricing=tiered"), "{stdout}");
    assert!(
        stdout.contains("REJ"),
        "tiered pricing at N=9 should reject the phone block:\n{stdout}"
    );
    let (_, ok) = qaci(&["fleet", "--admission-pricing", "free"]);
    assert!(!ok, "unknown pricing must be rejected");
}

/// The three named algorithms all produce valid allocations via the
/// dispatch entry point.
#[test]
fn algorithm_dispatch_and_parsing() {
    let fp = mixed(4);
    for (name, algorithm) in [
        ("proposed", FleetAlgorithm::Proposed),
        ("equal-share", FleetAlgorithm::EqualShare),
        ("feasible-random", FleetAlgorithm::FeasibleRandom),
    ] {
        assert_eq!(FleetAlgorithm::parse(name), Ok(algorithm));
        assert_eq!(algorithm.name(), name);
        // the legacy free-fn wrapper and the request API agree exactly
        let alloc = fleet::solve(&fp, algorithm, 13);
        let via_req = fp.solve(&SolveRequest { algorithm, seed: 13, ..SolveRequest::default() });
        assert_eq!(alloc.agents.len(), 4);
        assert!(alloc.objective.is_finite());
        assert_eq!(alloc.objective, via_req.objective);
    }
    assert_eq!(FleetAlgorithm::parse("equal"), Ok(FleetAlgorithm::EqualShare));
    let err = FleetAlgorithm::parse("nope").unwrap_err();
    assert_eq!(err.token, "nope");
    assert!(err.choices.contains(&"equal-share"), "choices must name the canonical spellings");
}

/// Acceptance: `qaci fleet --servers 3 --churn --events` exercises the
/// whole multi-server path — sticky placement, per-server warm re-solves,
/// per-server event queues — and completes with a verdict; the one-shot
/// path surfaces the srv column only at S > 1, so single-server output
/// is unchanged.
#[test]
fn cli_fleet_multi_server_end_to_end() {
    let (stdout, _) = qaci(&[
        "fleet", "--servers", "3", "--churn", "--events", "--horizon", "240", "--seed", "0",
    ]);
    assert!(stdout.contains("servers: S=3"), "multi-server header missing:\n{stdout}");
    assert!(stdout.contains("event-level telemetry"), "event table missing:\n{stdout}");
    for policy in ["static-equal", "static-proposed", "online-proposed"] {
        assert!(stdout.contains(policy), "policy {policy} missing:\n{stdout}");
    }
    // exit code reflects the online-vs-static verdict; either way the
    // replay must have finished cleanly enough to print it
    assert!(
        stdout.contains("online re-allocation") || stdout.contains("no churn events fired"),
        "no verdict line:\n{stdout}"
    );
    let (multi, ok) = qaci(&["fleet", "--agents", "6", "--servers", "2", "--requests", "4"]);
    assert!(ok, "S=2 one-shot run failed:\n{multi}");
    assert!(multi.contains("srv"), "srv column missing at S=2:\n{multi}");
    assert!(multi.contains("servers: S=2"), "{multi}");
    let (single, ok) = qaci(&["fleet", "--agents", "6", "--requests", "4"]);
    assert!(ok);
    assert!(!single.contains("srv"), "srv column must not appear at S=1:\n{single}");
    // unknown placement strategies and malformed scales are usage errors
    let (_, ok) = qaci(&["fleet", "--placement", "telepathy"]);
    assert!(!ok, "unknown placement must be rejected");
    let (_, ok) = qaci(&["fleet", "--server-scales", "1.0,zero"]);
    assert!(!ok, "bad server scales must be rejected");
}
