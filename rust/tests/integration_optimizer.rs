//! Optimizer integration: the four design algorithms compared head-to-head
//! across platforms and budget regimes — the backbone of Figs. 5–8.

use qaci::opt::{bisection, feasible_random, fixed_freq, sca, Problem};
use qaci::rl::env::BudgetRanges;
use qaci::rl::{DesignEnv, Ppo, PpoConfig};
use qaci::system::Platform;
use qaci::util::rng::Rng;

const LAMBDA: f64 = 15.0;

fn budgets() -> Vec<(f64, f64)> {
    vec![(2.5, 2.0), (3.0, 2.0), (3.5, 2.0), (4.0, 2.0), (3.5, 1.0), (3.5, 3.0)]
}

/// The paper's headline ordering: proposed >= every baseline, on every
/// budget, on both platforms (in objective terms; CIDEr follows in the
/// benches).
#[test]
fn proposed_dominates_baselines_in_objective() {
    for platform in [Platform::paper_blip2(), Platform::paper_git()] {
        for (t0, e0) in budgets() {
            let prob = Problem::new(platform, LAMBDA, t0, e0);
            let Some(proposed) = sca::solve(&prob, sca::ScaOptions::default()) else {
                continue;
            };
            let obj_proposed = prob.objective(proposed.design.b_hat as f64);

            if let Some(ff) = fixed_freq::solve(&prob) {
                assert!(
                    obj_proposed <= prob.objective(ff.b_hat as f64) + 1e-12,
                    "fixed-freq beat proposed at ({t0},{e0})"
                );
            }
            if let Some(mean) = feasible_random::mean_objective(&prob, 400, 42) {
                assert!(
                    obj_proposed <= mean + 1e-12,
                    "feasible-random mean beat proposed at ({t0},{e0})"
                );
            }
        }
    }
}

/// SCA tracks the exact optimum across the full budget grid.
#[test]
fn sca_tracks_exact_across_grid() {
    let mut worse = 0;
    let mut total = 0;
    for (t0, e0) in budgets() {
        let prob = Problem::new(Platform::paper_blip2(), LAMBDA, t0, e0);
        let (Some(s), Some(e)) =
            (sca::solve(&prob, sca::ScaOptions::default()), bisection::solve(&prob))
        else {
            continue;
        };
        total += 1;
        if s.design.b_hat < e.design.b_hat {
            worse += 1;
            assert!(
                e.design.b_hat - s.design.b_hat <= 1,
                "SCA lost >1 bit at ({t0},{e0})"
            );
        }
        assert!(s.design.b_hat <= e.design.b_hat, "SCA above exact?!");
    }
    assert!(total >= 5);
    assert!(worse <= total / 2, "SCA suboptimal too often: {worse}/{total}");
}

/// A trained PPO policy must beat an untrained one, and land within the
/// feasible region after projection — but (the paper's point) it does not
/// consistently match the proposed design.
#[test]
fn ppo_learns_but_does_not_dominate_proposed() {
    let platform = Platform::paper_blip2();
    let env = DesignEnv::new(platform, LAMBDA, BudgetRanges::default());
    let mut rng = Rng::new(3);
    let cfg = PpoConfig { iterations: 50, batch: 192, ..PpoConfig::default() };
    let untrained = Ppo::new(env.clone(), cfg, &mut rng);
    let mut trained = Ppo::new(env.clone(), cfg, &mut rng);
    trained.train(&mut rng);

    let mut eval_reward = |ppo: &Ppo, seed: u64| -> f64 {
        let mut r = Rng::new(seed);
        let mut total = 0.0;
        for _ in 0..200 {
            let p = env.sample_context(&mut r);
            let d = ppo.solve(&p);
            total += env.reward(&p, &d);
        }
        total / 200.0
    };
    let r_untrained = eval_reward(&untrained, 9);
    let r_trained = eval_reward(&trained, 9);
    assert!(
        r_trained > r_untrained + 0.05,
        "PPO did not learn: {r_untrained} -> {r_trained}"
    );

    // and the proposed design still wins on average objective
    let mut r = Rng::new(10);
    let mut ppo_obj = 0.0;
    let mut prop_obj = 0.0;
    let mut n = 0;
    for _ in 0..100 {
        let p = env.sample_context(&mut r);
        let (Some(pd), Some(sd)) =
            (trained.solve_projected(&p), bisection::solve(&p))
        else {
            continue;
        };
        ppo_obj += p.objective(pd.b_hat as f64);
        prop_obj += p.objective(sd.design.b_hat as f64);
        n += 1;
    }
    assert!(n > 50);
    assert!(
        prop_obj <= ppo_obj + 1e-9,
        "proposed {prop_obj} should be <= ppo {ppo_obj} over {n} contexts"
    );
}

/// Budget monotonicity of the whole pipeline: loosening either budget
/// never reduces the chosen bit-width (the Figs. 5-8 x-axis trend).
#[test]
fn bitwidth_monotone_in_budgets() {
    let t0s = [2.2, 2.6, 3.0, 3.4, 3.8, 4.2];
    let mut prev = 0u32;
    for t0 in t0s {
        let prob = Problem::new(Platform::paper_blip2(), LAMBDA, t0, 2.0);
        if let Some(r) = bisection::solve(&prob) {
            assert!(r.design.b_hat >= prev, "t0={t0}");
            prev = r.design.b_hat;
        }
    }
    let e0s = [0.6, 1.0, 1.4, 1.8, 2.2, 2.6];
    let mut prev = 0u32;
    for e0 in e0s {
        let prob = Problem::new(Platform::paper_blip2(), LAMBDA, 3.5, e0);
        if let Some(r) = bisection::solve(&prob) {
            assert!(r.design.b_hat >= prev, "e0={e0}");
            prev = r.design.b_hat;
        }
    }
}

/// The convex subproblem machinery agrees with the closed-form frequency
/// planner on the continuous relaxation (CVX-replacement regression test).
#[test]
fn sca_trace_converges() {
    let prob = Problem::new(Platform::paper_blip2(), LAMBDA, 3.5, 2.0);
    let r = sca::solve(&prob, sca::ScaOptions { max_iters: 40, tol: 1e-9 }).unwrap();
    // monotone non-increasing trace, final plateau
    for w in r.trace.windows(2) {
        assert!(w[1] <= w[0] + 1e-9);
    }
    let n = r.trace.len();
    if n >= 3 {
        assert!((r.trace[n - 1] - r.trace[n - 2]).abs() < 1e-3);
    }
}
