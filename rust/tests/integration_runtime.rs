//! Runtime integration: artifacts load, compile, and reproduce the golden
//! vectors python recorded at AOT time — proving the HLO-text interchange
//! and the Rust quantizer/weight plumbing are numerically faithful.

use qaci::data::eval::EvalSet;
use qaci::data::vocab::Vocab;
use qaci::metrics::stats;
use qaci::quant::{self, Scheme};
use qaci::runtime::executor::{CoModel, Fcdnn, QuantKernel};
use qaci::runtime::Registry;
use qaci::util::json::Json;

fn registry() -> Option<Registry> {
    let dir = qaci::artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(Registry::open(&dir).expect("open registry"))
}

fn read_bin(reg: &Registry, name: &str) -> Vec<f32> {
    std::fs::read(reg.dir.join(name))
        .expect("golden bin")
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect()
}

#[test]
fn golden_end_to_end_blip2ish_and_gitish() {
    let Some(reg) = registry() else { return };
    let golden = reg.golden().expect("golden.json");
    let vocab = Vocab::from_manifest(&reg.manifest).unwrap();

    for (model_name, eval_name) in [("blip2ish", "coco"), ("gitish", "vatex")] {
        let mut model = CoModel::load(&reg, model_name).expect("load model");
        let eval = EvalSet::load(&reg.dir, &reg.manifest, eval_name).unwrap();
        // golden vectors were produced on eval sample 0 at full precision
        let emb = model
            .encode(eval.sample(0), 1, 32, Scheme::Uniform)
            .expect("encode");
        let g = golden.get(model_name).expect("golden entry");
        let want_l1 = g.get("emb_l1").and_then(Json::as_f64).unwrap();
        let got_l1 = stats::l1(&emb);
        assert!(
            (got_l1 - want_l1).abs() / want_l1 < 1e-4,
            "{model_name} emb L1: got {got_l1} want {want_l1}"
        );
        let first8 = g.get("emb_first8").and_then(Json::as_arr).unwrap();
        for (i, w) in first8.iter().enumerate() {
            let want = w.as_f64().unwrap() as f32;
            assert!(
                (emb[i] - want).abs() < 1e-3 * (1.0 + want.abs()),
                "{model_name} emb[{i}]: {} vs {}",
                emb[i],
                want
            );
        }
        // greedy decode must match token-for-token
        let tokens = model.decode(&emb, 1).expect("decode");
        let want_tokens: Vec<i32> = g
            .get("tokens")
            .and_then(Json::as_arr)
            .unwrap()
            .iter()
            .map(|t| t.as_f64().unwrap() as i32)
            .collect();
        assert_eq!(tokens[0], want_tokens, "{model_name} token mismatch");
        let caption = vocab.detokenize(&tokens[0]);
        let want_caption = g.get("caption").and_then(Json::as_str).unwrap();
        assert_eq!(caption, want_caption, "{model_name} caption mismatch");
    }
}

#[test]
fn golden_fcdnn_forward() {
    let Some(reg) = registry() else { return };
    let golden = reg.golden().unwrap();
    let g = golden.get("fcdnn16").unwrap();
    let x = read_bin(&reg, g.get("input").and_then(Json::as_str).unwrap());
    let mut fcdnn = Fcdnn::load(&reg).expect("load fcdnn");
    let y = fcdnn.forward(&x).expect("forward");
    let want_l1 = g.get("out_l1").and_then(Json::as_f64).unwrap();
    let got_l1 = stats::l1(&y);
    assert!(
        (got_l1 - want_l1).abs() / want_l1 < 1e-4,
        "fcdnn L1 {got_l1} vs {want_l1}"
    );
    let first8 = g.get("out_first8").and_then(Json::as_arr).unwrap();
    for (i, w) in first8.iter().enumerate() {
        let want = w.as_f64().unwrap() as f32;
        assert!((y[i] - want).abs() < 1e-3 * (1.0 + want.abs()), "y[{i}]");
    }
}

/// The Rust quantizers and the Pallas fake-quant kernels (through PJRT)
/// must agree elementwise — one grid, two implementations.
#[test]
fn rust_quantizer_matches_pallas_kernel_through_pjrt() {
    let Some(reg) = registry() else { return };
    let golden = reg.golden().unwrap();
    let g = golden.get("quant").unwrap();
    let buf = read_bin(&reg, g.get("input").and_then(Json::as_str).unwrap());
    let kernel = QuantKernel::load(&reg).expect("quant kernel");
    assert_eq!(buf.len(), kernel.buf_len());

    // uniform @ step recorded in golden
    let step = g.get("uniform_step").and_then(Json::as_f64).unwrap() as f32;
    let xla_q = kernel.uniform(&buf, step).expect("xla uniform");
    let rust_q = quant::quantize_uniform(&buf, step);
    let mismatches = xla_q.iter().zip(&rust_q).filter(|(a, b)| a != b).count();
    // identical f32 ops; allow a vanishing number of half-way rounding
    // disagreements
    assert!(
        mismatches * 100_000 < buf.len(),
        "uniform: {mismatches}/{} mismatches",
        buf.len()
    );
    let want_l1 = g.get("uniform_l1").and_then(Json::as_f64).unwrap();
    assert!((stats::l1(&xla_q) - want_l1).abs() / want_l1 < 1e-5);

    // pot @ recorded exponent range
    let emin = g.get("pot_emin").and_then(Json::as_f64).unwrap() as f32;
    let emax = g.get("pot_emax").and_then(Json::as_f64).unwrap() as f32;
    let xla_p = kernel.pot(&buf, emin, emax).expect("xla pot");
    let rust_p = quant::quantize_pot(&buf, emin, emax);
    let mismatches = xla_p.iter().zip(&rust_p).filter(|(a, b)| a != b).count();
    assert!(
        mismatches * 100_000 < buf.len(),
        "pot: {mismatches}/{} mismatches",
        buf.len()
    );
    let want_l1 = g.get("pot_l1").and_then(Json::as_f64).unwrap();
    assert!((stats::l1(&xla_p) - want_l1).abs() / want_l1 < 1e-5);
}

#[test]
fn quantized_weights_cache_and_batching() {
    let Some(reg) = registry() else { return };
    let mut model = CoModel::load(&reg, "blip2ish").unwrap();
    let eval = EvalSet::load(&reg.dir, &reg.manifest, "coco").unwrap();

    // batched encode == per-sample encode (weights identical, batch exe)
    let n = 5; // forces a b4 chunk + a b1 chunk
    let mut inputs = Vec::new();
    for i in 0..n {
        inputs.extend_from_slice(eval.sample(i));
    }
    let batched = model.encode(&inputs, n, 6, Scheme::Uniform).unwrap();
    for i in 0..n {
        let single = model.encode(eval.sample(i), 1, 6, Scheme::Uniform).unwrap();
        let off = i * model.dims.emb_len();
        for (j, s) in single.iter().enumerate() {
            let b = batched[off + j];
            assert!(
                (b - s).abs() < 1e-4 * (1.0 + s.abs()),
                "sample {i} elem {j}: batched {b} vs single {s}"
            );
        }
    }
    // quantization cache holds the 6-bit entry
    assert!(model.agent_weights.cached_points() >= 1);
}

#[test]
fn manifest_lambda_matches_rust_fit() {
    let Some(reg) = registry() else { return };
    let model = CoModel::load(&reg, "blip2ish").unwrap();
    // python fit excluded layernorm params; the rust blob fit includes
    // them — agreement within 2x is enough (λ enters the bounds
    // multiplicatively and both fits are reported in benches)
    let rust_fit = qaci::theory::expdist::ExponentialModel::fit_weights(&model.agent_weights.blob);
    let ratio = rust_fit.lambda / model.agent_weights.lambda;
    assert!(
        (0.5..2.0).contains(&ratio),
        "rust {} vs manifest {}",
        rust_fit.lambda,
        model.agent_weights.lambda
    );
}

#[test]
fn caption_quality_degrades_monotonically_ish_with_bits() {
    // the quality-bitwidth curve the whole paper rides on: full precision
    // must beat 2-bit quantization on corpus CIDEr
    let Some(reg) = registry() else { return };
    let mut model = CoModel::load(&reg, "blip2ish").unwrap();
    let eval = EvalSet::load(&reg.dir, &reg.manifest, "coco").unwrap();
    let vocab = Vocab::from_manifest(&reg.manifest).unwrap();
    let scorer = qaci::metrics::cider::CiderScorer::new(&eval.refs);
    let n = 16usize;
    let mut score_at = |bits: u32| -> f64 {
        let mut total = 0.0;
        for i in 0..n {
            let toks = model.infer(eval.sample(i), 1, bits, Scheme::Uniform).unwrap();
            total += scorer.score_one(i, &vocab.detokenize(&toks[0]));
        }
        total / n as f64
    };
    let full = score_at(32);
    let low = score_at(2);
    assert!(
        full > low + 0.5,
        "expected clear quality gap: full {full} vs 2-bit {low}"
    );
    assert!(full > 3.0, "trained model should caption well, got {full}");
}
