//! Theory-vs-reality integration: the analytical machinery of §III–IV
//! checked against the *actual trained weights* shipped in artifacts, and
//! against real quantizers on synthetic exponential sources.

use qaci::quant::{self, Scheme};
use qaci::theory::blahut_arimoto::BlahutArimoto;
use qaci::theory::distortion;
use qaci::theory::expdist::ExponentialModel;
use qaci::theory::rate_distortion as rd;
use qaci::util::rng::Rng;

/// D^L <= D_BA <= D^U on a dense rate range (the Fig. 4 sandwich), for
/// several λ including the fitted values of the shipped models (~15).
#[test]
fn ba_sandwich_across_lambdas() {
    for lambda in [2.0, 15.0, 60.0] {
        // finer grid => more sweep points clear the discretization guard
        // (the guard excludes D within ~8 bins, where the discrete source's
        // D(R) legitimately dips below the continuous Shannon bound)
        let bins = 800;
        let ba = BlahutArimoto::exponential(lambda, bins, 12.0);
        let pts = ba.sweep(&BlahutArimoto::default_slopes(lambda), 300, 1e-8);
        let bin = 12.0 / lambda / bins as f64;
        let mut checked = 0;
        for p in pts.iter().filter(|p| p.rate_bits > 0.4 && p.distortion > 8.0 * bin) {
            assert!(p.distortion >= rd::d_lower(p.rate_bits, lambda) * 0.95,
                    "λ={lambda} R={} D={}", p.rate_bits, p.distortion);
            assert!(p.distortion <= rd::d_upper(p.rate_bits, lambda) * 1.02,
                    "λ={lambda} R={} D={}", p.rate_bits, p.distortion);
            checked += 1;
        }
        assert!(checked >= 4, "λ={lambda}: only {checked} points in range");
    }
}

/// Real scalar quantizers on an exponential source live inside the
/// theory's predicted band (above the Shannon floor; within a small
/// constant of the upper bound at moderate rates).
#[test]
fn real_quantizers_inside_predicted_band() {
    let mut rng = Rng::new(77);
    let lambda = 15.0;
    let w: Vec<f32> = (0..300_000)
        .map(|_| {
            let sign = if rng.f64() < 0.5 { -1.0 } else { 1.0 };
            (sign * rng.exponential(lambda)) as f32
        })
        .collect();
    for bits in 3..=9u32 {
        let rate = (bits - 1) as f64;
        let q = quant::quantize_magnitudes(&w, bits, Scheme::Uniform);
        let d = quant::mean_abs_distortion(&w, &q);
        assert!(d >= rd::d_lower(rate, lambda) * 0.9, "bits={bits} d={d}");
        assert!(d <= rd::d_upper(rate, lambda) * 4.0, "bits={bits} d={d}");
    }
}

/// Prop 3.1 + surrogate: for a real FC net under both quantizers, the
/// measured output distortion obeys the layered bound and tightens with
/// bit-width (the Fig. 3 phenomenon).
#[test]
fn fig3_shape_on_synthetic_fc_net() {
    let mut rng = Rng::new(5);
    let dims = [16usize, 32, 32, 8];
    let net: Vec<distortion::LayerMatrix> = dims
        .windows(2)
        .map(|w| {
            distortion::LayerMatrix::new(
                w[1],
                w[0],
                (0..w[0] * w[1]).map(|_| 0.25 * rng.normal() as f32).collect(),
            )
        })
        .collect();
    // normalized probe inputs
    let probes: Vec<Vec<f64>> = (0..8)
        .map(|_| {
            let mut x: Vec<f64> = (0..dims[0]).map(|_| rng.normal()).collect();
            let n: f64 = x.iter().map(|v| v.abs()).sum();
            x.iter_mut().for_each(|v| *v /= n);
            x
        })
        .collect();
    for scheme in [Scheme::Uniform, Scheme::Pot] {
        let mut prev_gap = f64::INFINITY;
        for bits in [2u32, 3, 4, 6, 8] {
            let qnet: Vec<distortion::LayerMatrix> = net
                .iter()
                .map(|m| {
                    distortion::LayerMatrix::new(
                        m.rows,
                        m.cols,
                        quant::quantize_magnitudes(&m.data, bits, scheme),
                    )
                })
                .collect();
            let bound = distortion::output_distortion_bound(&net, &qnet);
            let mut worst = 0.0f64;
            for x in &probes {
                let y = distortion::fc_forward(&net, x);
                let yq = distortion::fc_forward(&qnet, x);
                let d: f64 = y.iter().zip(&yq).map(|(a, b)| (a - b).abs()).sum();
                worst = worst.max(d);
            }
            assert!(worst <= bound + 1e-9, "{scheme:?}@{bits}: {worst} > {bound}");
            // the bound/measurement gap narrows as bits grow (Fig. 3)
            if bits >= 3 && bound > 0.0 {
                let gap = bound - worst;
                assert!(gap <= prev_gap * 1.5, "{scheme:?}@{bits} gap widened");
                prev_gap = gap;
            }
        }
    }
}

/// λ fitting on magnitudes from a *mixture* (like real model weights)
/// still produces a usable model: the KS statistic quantifies the misfit
/// and stays below the level where Fig. 2's visual fit would fail.
#[test]
fn lambda_fit_on_mixture_weights() {
    let mut rng = Rng::new(9);
    // half small normals, half wide normals — a crude trained-weight blob
    let mags: Vec<f64> = (0..100_000)
        .map(|i| {
            if i % 2 == 0 {
                (0.02 * rng.normal()).abs()
            } else {
                (0.08 * rng.normal()).abs()
            }
        })
        .collect();
    let m = ExponentialModel::fit(mags.iter().copied());
    assert!(m.lambda > 1.0);
    let ks = m.ks_statistic(&mags);
    assert!(ks < 0.25, "KS {ks} too large for a usable exponential fit");
}

/// Remark 3.2's empirical H: output distortion of the FC net is linearly
/// bounded by the surrogate parameter distortion, and the estimated H
/// bounds unseen bit-widths too.
#[test]
fn empirical_h_generalizes_across_bitwidths() {
    let mut rng = Rng::new(11);
    let dims = [12usize, 24, 12, 6];
    let net: Vec<distortion::LayerMatrix> = dims
        .windows(2)
        .map(|w| {
            distortion::LayerMatrix::new(
                w[1],
                w[0],
                (0..w[0] * w[1]).map(|_| 0.3 * rng.normal() as f32).collect(),
            )
        })
        .collect();
    let mut x: Vec<f64> = (0..dims[0]).map(|_| rng.normal()).collect();
    let n: f64 = x.iter().map(|v| v.abs()).sum();
    x.iter_mut().for_each(|v| *v /= n);

    let measure = |bits: u32| -> (f64, f64) {
        let qnet: Vec<distortion::LayerMatrix> = net
            .iter()
            .map(|m| {
                distortion::LayerMatrix::new(
                    m.rows,
                    m.cols,
                    quant::quantize_magnitudes(&m.data, bits, Scheme::Uniform),
                )
            })
            .collect();
        let param = distortion::surrogate_l1(&net, &qnet);
        let y = distortion::fc_forward(&net, &x);
        let yq = distortion::fc_forward(&qnet, &x);
        let out: f64 = y.iter().zip(&yq).map(|(a, b)| (a - b).abs()).sum();
        (param, out)
    };
    // estimate H on even bit-widths, verify on odd ones
    let train: Vec<(f64, f64)> = [2u32, 4, 6, 8].iter().map(|&b| measure(b)).collect();
    let h = distortion::empirical_h(&train);
    assert!(h > 0.0);
    for bits in [3u32, 5, 7] {
        let (param, out) = measure(bits);
        assert!(
            out <= h * param * 1.3 + 1e-9,
            "H={h} fails at {bits} bits: out {out} vs param {param}"
        );
    }
}
