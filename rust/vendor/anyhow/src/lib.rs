//! Vendored stand-in for the `anyhow` crate.
//!
//! The build container has no network access to crates.io, so this path
//! dependency implements the (small) API subset the qaci codebase uses:
//!
//! * [`Result<T>`] with a defaulted error type
//! * [`Error`] — a context-chained message error; `{e}` prints the
//!   outermost context, `{e:#}` prints the whole chain (anyhow's
//!   alternate formatting), `{e:?}` prints a "Caused by" report
//! * [`Context`] — `.context(...)` / `.with_context(|| ...)` on both
//!   `Result` and `Option`
//! * [`anyhow!`], [`ensure!`], [`bail!`] macros
//!
//! Dropping the real `anyhow` back in is a one-line Cargo.toml change;
//! nothing in the codebase relies on behavior beyond the above.

use std::fmt;

/// `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Context-chained error: `chain[0]` is the outermost context, the last
/// element is the root cause.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Build an error from a printable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context message (what `.context(...)` does).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// Outermost-to-root iterator over the context chain.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }

    /// The innermost (root cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        if f.alternate() {
            for cause in &self.chain[1..] {
                write!(f, ": {cause}")?;
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for (i, cause) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {cause}")?;
            }
        }
        Ok(())
    }
}

// Like real anyhow, `Error` deliberately does NOT implement
// `std::error::Error`, which is what makes this blanket `From` coherent
// (and makes `?` work on any std error).
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut source = e.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Error { chain }
    }
}

/// `.context(...)` / `.with_context(|| ...)` on `Result` and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T>;
    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E> Context<T> for std::result::Result<T, E>
where
    Error: From<E>,
{
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::from(e).context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or printable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an error if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !$cond {
            return Err($crate::Error::msg(concat!(
                "condition failed: ",
                stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($arg:tt)+) => {
        if !$cond {
            return Err($crate::anyhow!($($arg)+));
        }
    };
}

/// Return early with a formatted error.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)+) => {
        return Err($crate::anyhow!($($arg)+))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails_io() -> Result<()> {
        std::fs::read("/definitely/not/a/path/qaci")
            .map(|_| ())
            .context("reading config")
    }

    #[test]
    fn context_chains_and_formats() {
        let e = fails_io().unwrap_err();
        assert_eq!(format!("{e}"), "reading config");
        let alt = format!("{e:#}");
        assert!(alt.starts_with("reading config: "), "{alt}");
        assert!(alt.len() > "reading config: ".len());
        let dbg = format!("{e:?}");
        assert!(dbg.contains("Caused by"), "{dbg}");
    }

    #[test]
    fn option_context() {
        let none: Option<u32> = None;
        let e = none.context("value missing").unwrap_err();
        assert_eq!(format!("{e}"), "value missing");
        assert_eq!(Some(3).context("unused").unwrap(), 3);
    }

    #[test]
    fn macros() {
        fn check(n: usize) -> Result<usize> {
            ensure!(n > 2, "n too small: {n}");
            ensure!(n < 100);
            if n == 50 {
                bail!("fifty is right out");
            }
            Ok(n)
        }
        assert_eq!(check(10).unwrap(), 10);
        assert_eq!(format!("{}", check(1).unwrap_err()), "n too small: 1");
        assert!(format!("{}", check(200).unwrap_err()).contains("condition failed"));
        assert!(check(50).is_err());
        let e = anyhow!("x = {}", 42);
        assert_eq!(format!("{e}"), "x = 42");
    }
}
