//! Offline stub of the `xla` crate (xla_extension PJRT bindings).
//!
//! The container image ships no native XLA toolchain, so this vendored
//! path crate keeps the L3 runtime layer compiling and the host-side
//! plumbing testable:
//!
//! * [`Literal`] is **functional**: construction from shape + untyped
//!   bytes, and typed readback via [`Literal::to_vec`] work for real —
//!   the weight-store quantized-literal cache and its tests run
//!   unchanged.
//! * Compilation/execution ([`PjRtClient`], [`PjRtLoadedExecutable`])
//!   return [`Error`] with a "PJRT unavailable" message; everything that
//!   needs real model execution (artifact-backed benches/tests) already
//!   skips or surfaces errors when `artifacts/` is absent.
//!
//! Swapping in the real bindings is a one-line change in
//! `rust/Cargo.toml`; the API surface here mirrors exactly the calls the
//! codebase makes.

use std::fmt;
use std::path::Path;

/// Stub error: always a plain message.
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

type XlaResult<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> XlaResult<T> {
    Err(Error(format!(
        "{what}: PJRT unavailable (offline `xla` stub; point rust/Cargo.toml \
         at the real xla_extension bindings to execute models)"
    )))
}

/// Element types the codebase constructs literals with.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    Pred,
    S32,
    S64,
    U32,
    F32,
    F64,
}

impl ElementType {
    pub fn byte_size(self) -> usize {
        match self {
            ElementType::Pred => 1,
            ElementType::S32 | ElementType::U32 | ElementType::F32 => 4,
            ElementType::S64 | ElementType::F64 => 8,
        }
    }
}

/// Host tensor: shape + little-endian bytes. Fully functional.
#[derive(Debug, Clone)]
pub struct Literal {
    ty: ElementType,
    dims: Vec<usize>,
    data: Vec<u8>,
}

/// Types [`Literal::to_vec`] can read back.
pub trait NativeType: Copy {
    const ELEMENT: ElementType;
    fn from_le(bytes: &[u8]) -> Self;
}

impl NativeType for f32 {
    const ELEMENT: ElementType = ElementType::F32;
    fn from_le(b: &[u8]) -> f32 {
        f32::from_le_bytes([b[0], b[1], b[2], b[3]])
    }
}

impl NativeType for f64 {
    const ELEMENT: ElementType = ElementType::F64;
    fn from_le(b: &[u8]) -> f64 {
        f64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]])
    }
}

impl NativeType for i32 {
    const ELEMENT: ElementType = ElementType::S32;
    fn from_le(b: &[u8]) -> i32 {
        i32::from_le_bytes([b[0], b[1], b[2], b[3]])
    }
}

impl NativeType for i64 {
    const ELEMENT: ElementType = ElementType::S64;
    fn from_le(b: &[u8]) -> i64 {
        i64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]])
    }
}

impl NativeType for u32 {
    const ELEMENT: ElementType = ElementType::U32;
    fn from_le(b: &[u8]) -> u32 {
        u32::from_le_bytes([b[0], b[1], b[2], b[3]])
    }
}

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        ty: ElementType,
        dims: &[usize],
        data: &[u8],
    ) -> XlaResult<Literal> {
        let n: usize = dims.iter().product();
        if n * ty.byte_size() != data.len() {
            return Err(Error(format!(
                "literal shape {dims:?} ({ty:?}) wants {} bytes, got {}",
                n * ty.byte_size(),
                data.len()
            )));
        }
        Ok(Literal { ty, dims: dims.to_vec(), data: data.to_vec() })
    }

    pub fn element_count(&self) -> usize {
        self.dims.iter().product()
    }

    pub fn shape_dims(&self) -> &[usize] {
        &self.dims
    }

    /// Typed readback (checked against the stored element type).
    pub fn to_vec<T: NativeType>(&self) -> XlaResult<Vec<T>> {
        if T::ELEMENT != self.ty {
            return Err(Error(format!(
                "literal holds {:?}, asked to read {:?}",
                self.ty,
                T::ELEMENT
            )));
        }
        Ok(self
            .data
            .chunks_exact(self.ty.byte_size())
            .map(T::from_le)
            .collect())
    }

    /// Untuple a 1-tuple result. Only execution produces tuples, which the
    /// stub cannot do, so this is unreachable in practice.
    pub fn to_tuple1(self) -> XlaResult<Literal> {
        unavailable("Literal::to_tuple1")
    }
}

/// Parsed HLO module handle (stub: never constructible).
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(path: P) -> XlaResult<HloModuleProto> {
        unavailable(&format!(
            "HloModuleProto::from_text_file({})",
            path.as_ref().display()
        ))
    }
}

/// Computation wrapper.
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

/// PJRT client (stub: construction reports unavailability).
pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> XlaResult<PjRtClient> {
        unavailable("PjRtClient::cpu")
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> XlaResult<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }
}

/// Loaded executable handle.
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> XlaResult<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

/// Device buffer handle.
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> XlaResult<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let xs = [1.0f32, -2.5, 3.25, 0.0, 5.5, -6.0];
        let bytes: Vec<u8> = xs.iter().flat_map(|v| v.to_le_bytes()).collect();
        let lit = Literal::create_from_shape_and_untyped_data(ElementType::F32, &[2, 3], &bytes)
            .unwrap();
        assert_eq!(lit.element_count(), 6);
        assert_eq!(lit.to_vec::<f32>().unwrap(), xs);
    }

    #[test]
    fn literal_shape_mismatch_rejected() {
        assert!(Literal::create_from_shape_and_untyped_data(
            ElementType::F32,
            &[4],
            &[0u8; 12],
        )
        .is_err());
    }

    #[test]
    fn literal_type_mismatch_rejected() {
        let lit = Literal::create_from_shape_and_untyped_data(ElementType::F32, &[1], &[0u8; 4])
            .unwrap();
        assert!(lit.to_vec::<i32>().is_err());
    }

    #[test]
    fn execution_paths_report_unavailable() {
        let e = PjRtClient::cpu().err().unwrap();
        assert!(e.to_string().contains("PJRT unavailable"), "{e}");
        assert!(HloModuleProto::from_text_file("x.hlo").is_err());
    }
}
